//! The online update policy: a decaying mini-batch schedule.
//!
//! Stochastic/online variational treatments of latent variable models
//! (Hoffman et al.'s online LDA; Archambeau & Ermis's incremental
//! variational framework) weight each mini-batch's contribution by a
//! Robbins–Monro step size
//!
//! ```text
//! ρ_t = s · (τ + t)^(−κ),   κ ∈ (0.5, 1]
//! ```
//!
//! so early batches move the model a lot and late batches refine it,
//! with Σρ_t = ∞ and Σρ_t² < ∞ guaranteeing convergence. A collapsed
//! Gibbs sampler has no explicit step size to decay — each ingested
//! token permanently joins the count matrices with weight 1. What *is*
//! free to schedule is **how much sampling effort each mini-batch
//! gets**: the number of full Gibbs sweeps the live session runs after
//! ingesting a batch. [`OnlinePolicy`] maps the Archambeau-style decay
//! onto that knob — batch `t` receives `round(base · ρ_t/ρ_1)` sweeps,
//! clamped to `[min, max]` — so the early stream (where the model is
//! still plastic and per-batch mixing matters most) gets the most
//! sweeps, and the late stream (where each batch is a small perturbation
//! of a converged model) amortizes down to the floor. The floor is never
//! 0: every batch must be sampled at least once or its tokens would sit
//! at their random initialization.

use crate::Result;

/// Decaying sweeps-per-mini-batch schedule (see the module docs).
#[derive(Clone, Debug)]
pub struct OnlinePolicy {
    kappa: f64,
    tau: f64,
    base_sweeps: u64,
    min_sweeps: u64,
    max_sweeps: u64,
}

impl OnlinePolicy {
    /// A policy with decay exponent `kappa` (must lie in `(0.5, 1]`, the
    /// Robbins–Monro range), delay `tau ≥ 0` (larger = slower early
    /// decay), and `base_sweeps ≥ 1` sweeps for the first batch. Bounds
    /// default to `[1, base_sweeps]`.
    pub fn new(kappa: f64, tau: f64, base_sweeps: u64) -> Result<OnlinePolicy> {
        anyhow::ensure!(
            kappa > 0.5 && kappa <= 1.0,
            "kappa must lie in (0.5, 1] — the Robbins–Monro range where \
             the step series diverges but its squares converge — got {kappa}"
        );
        anyhow::ensure!(
            tau.is_finite() && tau >= 0.0,
            "tau must be a finite non-negative delay, got {tau}"
        );
        anyhow::ensure!(base_sweeps >= 1, "base_sweeps must be ≥ 1");
        Ok(OnlinePolicy {
            kappa,
            tau,
            base_sweeps,
            min_sweeps: 1,
            max_sweeps: base_sweeps,
        })
    }

    /// Override the sweep clamp (`1 ≤ min ≤ max`).
    pub fn with_bounds(mut self, min_sweeps: u64, max_sweeps: u64) -> Result<OnlinePolicy> {
        anyhow::ensure!(
            min_sweeps >= 1 && min_sweeps <= max_sweeps,
            "sweep bounds must satisfy 1 ≤ min ≤ max, got [{min_sweeps}, {max_sweeps}]"
        );
        self.min_sweeps = min_sweeps;
        self.max_sweeps = max_sweeps;
        Ok(self)
    }

    /// The raw step weight `ρ_t = (τ + t)^(−κ)` for 1-based batch `t`.
    pub fn rho(&self, t: u64) -> f64 {
        (self.tau + t.max(1) as f64).powf(-self.kappa)
    }

    /// Gibbs sweeps 1-based batch `t` receives:
    /// `clamp(round(base · ρ_t/ρ_1), min, max)`.
    pub fn sweeps_for(&self, t: u64) -> u64 {
        let scale = self.rho(t) / self.rho(1);
        let s = (self.base_sweeps as f64 * scale).round() as u64;
        s.clamp(self.min_sweeps, self.max_sweeps)
    }
}

impl Default for OnlinePolicy {
    /// `κ = 0.7, τ = 1, base = 4` — mid-range decay, a common default in
    /// the online-LDA literature.
    fn default() -> OnlinePolicy {
        OnlinePolicy::new(0.7, 1.0, 4).expect("default policy is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kappa_outside_robbins_monro_is_refused() {
        for bad in [0.5, 0.49, 1.01, 0.0, -1.0] {
            let err = format!("{:#}", OnlinePolicy::new(bad, 1.0, 4).unwrap_err());
            assert!(err.contains("kappa"), "{err}");
        }
        assert!(OnlinePolicy::new(0.7, f64::NAN, 4).is_err());
        assert!(OnlinePolicy::new(0.7, -1.0, 4).is_err());
        assert!(OnlinePolicy::new(0.7, 1.0, 0).is_err());
        assert!(OnlinePolicy::new(0.7, 1.0, 4)
            .unwrap()
            .with_bounds(3, 2)
            .is_err());
    }

    #[test]
    fn sweeps_decay_monotonically_to_the_floor() {
        let p = OnlinePolicy::new(0.9, 1.0, 8).unwrap();
        assert_eq!(p.sweeps_for(1), 8, "first batch gets the full base");
        let schedule: Vec<u64> = (1..=200).map(|t| p.sweeps_for(t)).collect();
        for w in schedule.windows(2) {
            assert!(w[1] <= w[0], "sweep counts never increase: {schedule:?}");
        }
        assert_eq!(*schedule.last().unwrap(), 1, "late batches hit the floor");
        assert!(schedule.iter().all(|&s| (1..=8).contains(&s)));
    }

    #[test]
    fn higher_kappa_decays_faster() {
        let fast = OnlinePolicy::new(1.0, 1.0, 8).unwrap();
        let slow = OnlinePolicy::new(0.6, 1.0, 8).unwrap();
        for t in [5u64, 20, 80] {
            assert!(
                fast.sweeps_for(t) <= slow.sweeps_for(t),
                "κ=1.0 must not outspend κ=0.6 at batch {t}"
            );
        }
        // And a large τ delays the decay.
        let delayed = OnlinePolicy::new(1.0, 100.0, 8).unwrap();
        assert!(delayed.sweeps_for(5) > fast.sweeps_for(5));
    }
}
