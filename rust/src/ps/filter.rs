//! User-defined communication filters (§5.3).
//!
//! The paper's filter "sends the parameters with priority proportional to
//! the magnitude of the updates since synchronized last time" plus "a
//! uniform sampling strategy ... to avoid stale parameters even if they
//! have small local updates". [`Filter::select`] implements exactly that
//! pair: the top-`fraction` rows by L1 delta magnitude are sent, every
//! other row is sent with probability `uniform_prob`, and unsent rows are
//! *retained* (their deltas re-queued) for a later push.
//!
//! Two refinements over a plain sort-and-cut:
//!
//! - Row selection uses a partial selection (quickselect) instead of a
//!   full sort — O(rows) expected instead of O(rows log rows); the sent
//!   set is identical, only its internal order differs (the server fold
//!   is order-insensitive).
//! - `cell_level` ranks individual `(word, topic)` cells by |δ| rather
//!   than whole rows by L1. At K ≥ 10k a hot word's row mixes a few large
//!   deltas with thousands of ±1s; cell granularity sends the former now
//!   and re-queues the latter, shrinking wire bytes for the same staleness
//!   budget. Split rows go out as topic-sorted [`RowData::Sparse`] halves;
//!   a row whose cells all land on one side keeps its original encoding,
//!   so default-path wire bytes are bit-identical.

use super::msg::RowData;
use crate::util::rng::Rng;

/// Filter configuration.
#[derive(Clone, Copy, Debug)]
pub struct Filter {
    /// Fraction of candidate rows (or cells, when `cell_level`) sent by
    /// magnitude priority (1.0 = send everything, disabling the filter).
    pub magnitude_fraction: f64,
    /// Probability a non-selected row/cell is sent anyway (staleness
    /// guard).
    pub uniform_prob: f64,
    /// Rank individual `(word, topic)` cells by |δ| instead of whole
    /// rows by L1. Off by default: row mode is the paper's filter and
    /// keeps wire encodings untouched.
    pub cell_level: bool,
}

impl Default for Filter {
    fn default() -> Self {
        Filter {
            magnitude_fraction: 1.0,
            uniform_prob: 0.0,
            cell_level: false,
        }
    }
}

/// Visit the non-zero cells of either wire encoding in topic order.
fn for_each_cell(row: &RowData, mut f: impl FnMut(u32, i32)) {
    match row {
        RowData::Dense(cells) => {
            for (t, &v) in cells.iter().enumerate() {
                if v != 0 {
                    f(t as u32, v);
                }
            }
        }
        RowData::Sparse(pairs) => {
            for &(t, v) in pairs {
                if v != 0 {
                    f(t, v);
                }
            }
        }
    }
}

impl Filter {
    /// A filter matching the paper's description with sensible defaults.
    pub fn magnitude_priority() -> Self {
        Filter {
            magnitude_fraction: 0.5,
            uniform_prob: 0.1,
            cell_level: false,
        }
    }

    /// Partition candidate `(word, delta-row)` batches into
    /// `(send_now, retain)`. Rows arrive in either wire form; the L1
    /// priority key reads whichever encoding is present.
    pub fn select(
        &self,
        mut rows: Vec<(u32, RowData)>,
        rng: &mut Rng,
    ) -> (Vec<(u32, RowData)>, Vec<(u32, RowData)>) {
        if self.magnitude_fraction >= 1.0 || rows.len() <= 1 {
            return (rows, Vec::new());
        }
        if self.cell_level {
            return self.select_cells(rows, rng);
        }
        let cut = ((rows.len() as f64) * self.magnitude_fraction).ceil() as usize;
        let cut = cut.clamp(1, rows.len());
        // Partial selection: rows[..cut] holds the top-`cut` by L1
        // (unordered) — O(rows) expected, no full sort.
        if cut < rows.len() {
            rows.select_nth_unstable_by_key(cut - 1, |(_, r)| std::cmp::Reverse(r.l1()));
        }
        let mut send = Vec::with_capacity(cut);
        let mut retain = Vec::new();
        for (i, row) in rows.into_iter().enumerate() {
            if i < cut || rng.coin(self.uniform_prob) {
                send.push(row);
            } else {
                retain.push(row);
            }
        }
        (send, retain)
    }

    /// Cell-granularity selection: rank every non-zero `(word, topic)`
    /// cell by |δ|, send the top `magnitude_fraction` of cells (ties
    /// broken deterministically in input order), coin-rescue the rest,
    /// and re-queue whatever remains. Lossless: the cell multiset of
    /// `send ∪ retain` equals the input's.
    fn select_cells(
        &self,
        rows: Vec<(u32, RowData)>,
        rng: &mut Rng,
    ) -> (Vec<(u32, RowData)>, Vec<(u32, RowData)>) {
        let mut mags: Vec<u32> = Vec::new();
        for (_, r) in &rows {
            for_each_cell(r, |_, v| mags.push(v.unsigned_abs()));
        }
        let total = mags.len();
        if total == 0 {
            return (rows, Vec::new());
        }
        let cut = ((total as f64) * self.magnitude_fraction).ceil() as usize;
        let cut = cut.clamp(1, total);
        if cut >= total {
            return (rows, Vec::new());
        }
        let (_, &mut thresh, _) =
            mags.select_nth_unstable_by_key(cut - 1, |&m| std::cmp::Reverse(m));
        let above = mags.iter().filter(|&&m| m > thresh).count();
        // Cells strictly above the threshold always go; threshold ties
        // share the remaining budget first-come-first-served so the sent
        // cell count is exactly `cut` before any coin rescues.
        let mut quota = cut - above;
        let mut send = Vec::new();
        let mut retain = Vec::new();
        for (w, row) in rows {
            let mut send_cells: Vec<(u32, i32)> = Vec::new();
            let mut keep_cells: Vec<(u32, i32)> = Vec::new();
            for_each_cell(&row, |t, v| {
                let m = v.unsigned_abs();
                let hit = m > thresh
                    || (m == thresh && quota > 0 && {
                        quota -= 1;
                        true
                    });
                if hit || rng.coin(self.uniform_prob) {
                    send_cells.push((t, v));
                } else {
                    keep_cells.push((t, v));
                }
            });
            if keep_cells.is_empty() {
                // Whole row selected (or empty): keep the original
                // encoding byte-for-byte.
                send.push((w, row));
            } else if send_cells.is_empty() {
                retain.push((w, row));
            } else {
                // `for_each_cell` visits topics in order, so both halves
                // honour the sorted-sparse wire invariant.
                send.push((w, RowData::Sparse(send_cells)));
                retain.push((w, RowData::Sparse(keep_cells)));
            }
        }
        (send, retain)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(mags: &[i32]) -> Vec<(u32, RowData)> {
        mags.iter()
            .enumerate()
            .map(|(w, &m)| (w as u32, RowData::Dense(vec![m, 0, 0].into_boxed_slice())))
            .collect()
    }

    #[test]
    fn passthrough_when_fraction_one() {
        let f = Filter::default();
        let mut rng = Rng::new(1);
        let (send, retain) = f.select(rows(&[1, 2, 3]), &mut rng);
        assert_eq!(send.len(), 3);
        assert!(retain.is_empty());
    }

    #[test]
    fn magnitude_priority_keeps_biggest() {
        let f = Filter {
            magnitude_fraction: 0.34,
            uniform_prob: 0.0,
            cell_level: false,
        };
        let mut rng = Rng::new(2);
        let (send, retain) = f.select(rows(&[1, 100, 5, 50, 2, 3]), &mut rng);
        assert_eq!(send.len(), 3); // ceil(6 * 0.34) = 3
        let sent_words: Vec<u32> = send.iter().map(|(w, _)| *w).collect();
        assert!(sent_words.contains(&1)); // |100|
        assert!(sent_words.contains(&3)); // |50|
        assert_eq!(send.len() + retain.len(), 6);
    }

    #[test]
    fn uniform_sampling_rescues_small_rows() {
        let f = Filter {
            magnitude_fraction: 0.1,
            uniform_prob: 0.5,
            cell_level: false,
        };
        let mut rng = Rng::new(3);
        let mut rescued = 0;
        for _ in 0..200 {
            let (send, _) = f.select(rows(&[100, 1, 1, 1, 1, 1, 1, 1, 1, 1]), &mut rng);
            rescued += send.len() - 1; // beyond the magnitude pick
        }
        // E[rescued per call] = 9 * 0.5 = 4.5.
        assert!((600..1200).contains(&rescued), "rescued {rescued}");
    }

    #[test]
    fn nothing_lost() {
        let f = Filter::magnitude_priority();
        let mut rng = Rng::new(4);
        let input = rows(&[5, 3, 8, 1, 9, 2, 7]);
        let words_in: std::collections::BTreeSet<u32> = input.iter().map(|(w, _)| *w).collect();
        let (send, retain) = f.select(input, &mut rng);
        let words_out: std::collections::BTreeSet<u32> = send
            .iter()
            .chain(retain.iter())
            .map(|(w, _)| *w)
            .collect();
        assert_eq!(words_in, words_out);
    }

    /// Collect the `(word, topic, value)` cell multiset of a batch.
    fn cells_of(batch: &[(u32, RowData)]) -> Vec<(u32, u32, i32)> {
        let mut out = Vec::new();
        for (w, r) in batch {
            for_each_cell(r, |t, v| out.push((*w, t, v)));
        }
        out.sort_unstable();
        out
    }

    #[test]
    fn cell_level_sends_exact_budget_of_biggest_cells() {
        let f = Filter {
            magnitude_fraction: 0.25,
            uniform_prob: 0.0,
            cell_level: true,
        };
        let mut rng = Rng::new(5);
        // 8 non-zero cells across 3 words; top-2 by |δ| are (w0,t1)=-9
        // and (w2,t0)=7.
        let input = vec![
            (0u32, RowData::Dense(vec![1, -9, 2].into_boxed_slice())),
            (1u32, RowData::Sparse(vec![(0, 3), (2, -2)])),
            (2u32, RowData::Dense(vec![7, 0, 4].into_boxed_slice())),
        ];
        let (send, retain) = f.select(input, &mut rng);
        let sent = cells_of(&send);
        assert_eq!(sent, vec![(0, 1, -9), (2, 0, 7)]); // ceil(8·0.25) = 2
        assert_eq!(cells_of(&retain).len(), 6);
    }

    #[test]
    fn cell_level_breaks_ties_deterministically() {
        let f = Filter {
            magnitude_fraction: 0.5,
            uniform_prob: 0.0,
            cell_level: true,
        };
        // Four equal-magnitude cells: the budget (2) goes to the first
        // two in input order, every run.
        for seed in 0..8 {
            let mut rng = Rng::new(seed);
            let input = vec![
                (0u32, RowData::Sparse(vec![(0, 5), (1, -5)])),
                (1u32, RowData::Sparse(vec![(0, -5), (1, 5)])),
            ];
            let (send, _) = f.select(input, &mut rng);
            assert_eq!(cells_of(&send), vec![(0, 0, 5), (0, 1, -5)]);
        }
    }

    #[test]
    fn cell_level_loses_nothing_and_keeps_wire_invariants() {
        let f = Filter {
            magnitude_fraction: 0.4,
            uniform_prob: 0.25,
            cell_level: true,
        };
        let mut rng = Rng::new(6);
        let input = vec![
            (3u32, RowData::Dense(vec![0, 2, -8, 1].into_boxed_slice())),
            (7u32, RowData::Sparse(vec![(1, 1), (3, -4)])),
            (9u32, RowData::Dense(vec![6, 0, 0, 6].into_boxed_slice())),
            (11u32, RowData::Sparse(vec![(0, 1)])),
        ];
        let before = cells_of(&input);
        let (send, retain) = f.select(input, &mut rng);
        let mut after = cells_of(&send);
        after.extend(cells_of(&retain));
        after.sort_unstable();
        assert_eq!(before, after);
        // Split halves must be topic-sorted sparse rows.
        for (_, r) in send.iter().chain(retain.iter()) {
            if let RowData::Sparse(pairs) = r {
                assert!(pairs.windows(2).all(|p| p[0].0 < p[1].0));
            }
        }
    }

    #[test]
    fn cell_level_all_zero_rows_pass_through() {
        let f = Filter {
            magnitude_fraction: 0.5,
            uniform_prob: 0.0,
            cell_level: true,
        };
        let mut rng = Rng::new(7);
        let input = vec![
            (0u32, RowData::Dense(vec![0, 0].into_boxed_slice())),
            (1u32, RowData::Sparse(Vec::new())),
        ];
        let (send, retain) = f.select(input, &mut rng);
        assert_eq!(send.len(), 2);
        assert!(retain.is_empty());
    }
}
