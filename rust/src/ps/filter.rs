//! User-defined communication filters (§5.3).
//!
//! The paper's filter "sends the parameters with priority proportional to
//! the magnitude of the updates since synchronized last time" plus "a
//! uniform sampling strategy ... to avoid stale parameters even if they
//! have small local updates". [`Filter::select`] implements exactly that
//! pair: the top-`fraction` rows by L1 delta magnitude are sent, every
//! other row is sent with probability `uniform_prob`, and unsent rows are
//! *retained* (their deltas re-queued) for a later push.

use super::msg::RowData;
use crate::util::rng::Rng;

/// Filter configuration.
#[derive(Clone, Copy, Debug)]
pub struct Filter {
    /// Fraction of candidate rows sent by magnitude priority (1.0 = send
    /// everything, disabling the filter).
    pub magnitude_fraction: f64,
    /// Probability a non-selected row is sent anyway (staleness guard).
    pub uniform_prob: f64,
}

impl Default for Filter {
    fn default() -> Self {
        Filter {
            magnitude_fraction: 1.0,
            uniform_prob: 0.0,
        }
    }
}

impl Filter {
    /// A filter matching the paper's description with sensible defaults.
    pub fn magnitude_priority() -> Self {
        Filter {
            magnitude_fraction: 0.5,
            uniform_prob: 0.1,
        }
    }

    /// Partition candidate `(word, delta-row)` batches into
    /// `(send_now, retain)`. Rows arrive in either wire form; the L1
    /// priority key reads whichever encoding is present.
    pub fn select(
        &self,
        mut rows: Vec<(u32, RowData)>,
        rng: &mut Rng,
    ) -> (Vec<(u32, RowData)>, Vec<(u32, RowData)>) {
        if self.magnitude_fraction >= 1.0 || rows.len() <= 1 {
            return (rows, Vec::new());
        }
        // Sort by descending L1 magnitude.
        rows.sort_by_cached_key(|(_, r)| std::cmp::Reverse(r.l1()));
        let cut = ((rows.len() as f64) * self.magnitude_fraction).ceil() as usize;
        let cut = cut.clamp(1, rows.len());
        let mut send = Vec::with_capacity(cut);
        let mut retain = Vec::new();
        for (i, row) in rows.into_iter().enumerate() {
            if i < cut || rng.coin(self.uniform_prob) {
                send.push(row);
            } else {
                retain.push(row);
            }
        }
        (send, retain)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(mags: &[i32]) -> Vec<(u32, RowData)> {
        mags.iter()
            .enumerate()
            .map(|(w, &m)| (w as u32, RowData::Dense(vec![m, 0, 0].into_boxed_slice())))
            .collect()
    }

    #[test]
    fn passthrough_when_fraction_one() {
        let f = Filter::default();
        let mut rng = Rng::new(1);
        let (send, retain) = f.select(rows(&[1, 2, 3]), &mut rng);
        assert_eq!(send.len(), 3);
        assert!(retain.is_empty());
    }

    #[test]
    fn magnitude_priority_keeps_biggest() {
        let f = Filter {
            magnitude_fraction: 0.34,
            uniform_prob: 0.0,
        };
        let mut rng = Rng::new(2);
        let (send, retain) = f.select(rows(&[1, 100, 5, 50, 2, 3]), &mut rng);
        assert_eq!(send.len(), 3); // ceil(6 * 0.34) = 3
        let sent_words: Vec<u32> = send.iter().map(|(w, _)| *w).collect();
        assert!(sent_words.contains(&1)); // |100|
        assert!(sent_words.contains(&3)); // |50|
        assert_eq!(send.len() + retain.len(), 6);
    }

    #[test]
    fn uniform_sampling_rescues_small_rows() {
        let f = Filter {
            magnitude_fraction: 0.1,
            uniform_prob: 0.5,
        };
        let mut rng = Rng::new(3);
        let mut rescued = 0;
        for _ in 0..200 {
            let (send, _) = f.select(rows(&[100, 1, 1, 1, 1, 1, 1, 1, 1, 1]), &mut rng);
            rescued += send.len() - 1; // beyond the magnitude pick
        }
        // E[rescued per call] = 9 * 0.5 = 4.5.
        assert!((600..1200).contains(&rescued), "rescued {rescued}");
    }

    #[test]
    fn nothing_lost() {
        let f = Filter::magnitude_priority();
        let mut rng = Rng::new(4);
        let input = rows(&[5, 3, 8, 1, 9, 2, 7]);
        let words_in: std::collections::BTreeSet<u32> = input.iter().map(|(w, _)| *w).collect();
        let (send, retain) = f.select(input, &mut rng);
        let words_out: std::collections::BTreeSet<u32> = send
            .iter()
            .chain(retain.iter())
            .map(|(w, _)| *w)
            .collect();
        assert_eq!(words_in, words_out);
    }
}
