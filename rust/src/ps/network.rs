//! The simulated cluster transport.
//!
//! Substitution for the paper's shared production datacenter network: each
//! node owns an inbox (a delivery-time-ordered heap + condvar); `send`
//! stamps a deterministic latency (base + jitter), may drop the message,
//! and respects node kills. All the distributed phenomena the paper's
//! machinery answers — staleness, reordering, loss, failover — arise from
//! these three knobs.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use super::msg::{Envelope, NodeId, Payload};
use crate::util::rng::Rng;

/// Transport knobs.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Base one-way latency.
    pub base_latency: Duration,
    /// Uniform jitter added on top.
    pub jitter: Duration,
    /// Probability a message is silently dropped.
    pub drop_prob: f64,
    /// RNG seed for latency/drop decisions.
    pub seed: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            base_latency: Duration::from_micros(200),
            jitter: Duration::from_micros(300),
            drop_prob: 0.0,
            seed: 0xC0FFEE,
        }
    }
}

/// Aggregate transport statistics.
#[derive(Debug, Default)]
pub struct NetStats {
    /// Messages accepted for delivery.
    pub sent: AtomicU64,
    /// Messages dropped by loss injection.
    pub dropped: AtomicU64,
    /// Messages refused because the destination is dead.
    pub dead_letters: AtomicU64,
    /// Total payload bytes accepted.
    pub bytes: AtomicU64,
}

struct Inbox {
    q: Mutex<BinaryHeap<Envelope>>,
    cv: Condvar,
}

impl Inbox {
    fn new() -> Self {
        Inbox {
            q: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
        }
    }
}

struct Inner {
    inboxes: RwLock<Vec<Arc<Inbox>>>,
    dead: RwLock<Vec<Arc<AtomicBool>>>,
    cfg: NetConfig,
    rng: Mutex<Rng>,
    seq: AtomicU64,
    stats: NetStats,
    /// Degraded-mode override (chaos spikes): extra one-way latency in
    /// nanoseconds added to every message while non-zero.
    extra_latency_ns: AtomicU64,
    /// Degraded-mode override: extra drop probability in milli-units
    /// (0..=1000) added to `cfg.drop_prob` while non-zero.
    extra_drop_milli: AtomicU64,
}

/// Handle to the simulated network (cheaply cloneable).
#[derive(Clone)]
pub struct SimNet {
    inner: Arc<Inner>,
}

impl SimNet {
    /// Create a network with `n_nodes` pre-registered nodes.
    pub fn new(n_nodes: usize, cfg: NetConfig) -> Self {
        let seed = cfg.seed;
        SimNet {
            inner: Arc::new(Inner {
                inboxes: RwLock::new((0..n_nodes).map(|_| Arc::new(Inbox::new())).collect()),
                dead: RwLock::new((0..n_nodes).map(|_| Arc::new(AtomicBool::new(false))).collect()),
                cfg,
                rng: Mutex::new(Rng::new(seed)),
                seq: AtomicU64::new(0),
                stats: NetStats::default(),
                extra_latency_ns: AtomicU64::new(0),
                extra_drop_milli: AtomicU64::new(0),
            }),
        }
    }

    /// Register a new node (failover replacements). Returns its id.
    pub fn add_node(&self) -> NodeId {
        let mut inboxes = self.inner.inboxes.write().unwrap();
        let mut dead = self.inner.dead.write().unwrap();
        inboxes.push(Arc::new(Inbox::new()));
        dead.push(Arc::new(AtomicBool::new(false)));
        (inboxes.len() - 1) as NodeId
    }

    /// Number of registered nodes (alive or dead).
    pub fn len(&self) -> usize {
        self.inner.inboxes.read().unwrap().len()
    }

    /// True iff no nodes.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Transport statistics.
    pub fn stats(&self) -> (u64, u64, u64, u64) {
        let s = &self.inner.stats;
        (
            s.sent.load(Ordering::Relaxed),
            s.dropped.load(Ordering::Relaxed),
            s.dead_letters.load(Ordering::Relaxed),
            s.bytes.load(Ordering::Relaxed),
        )
    }

    /// Mark a node dead: its inbox stops accepting and is flushed.
    pub fn kill(&self, node: NodeId) {
        let dead = self.inner.dead.read().unwrap();
        if let Some(d) = dead.get(node as usize) {
            d.store(true, Ordering::SeqCst);
        }
        let inboxes = self.inner.inboxes.read().unwrap();
        if let Some(ib) = inboxes.get(node as usize) {
            ib.q.lock().unwrap().clear();
            ib.cv.notify_all();
        }
    }

    /// Is the node dead?
    pub fn is_dead(&self, node: NodeId) -> bool {
        self.inner
            .dead
            .read()
            .unwrap()
            .get(node as usize)
            .map(|d| d.load(Ordering::SeqCst))
            .unwrap_or(true)
    }

    /// Send `payload` from `from` to `to`. Returns `false` if the message
    /// was dropped (loss injection) or refused (dead destination).
    pub fn send(&self, from: NodeId, to: NodeId, payload: Payload) -> bool {
        if self.is_dead(to) || self.is_dead(from) {
            self.inner.stats.dead_letters.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let extra_ns = self.inner.extra_latency_ns.load(Ordering::Relaxed);
        let extra_drop = self.inner.extra_drop_milli.load(Ordering::Relaxed) as f64 / 1000.0;
        let (latency, dropped) = {
            let mut rng = self.inner.rng.lock().unwrap();
            let jit = self.inner.cfg.jitter.as_nanos() as f64 * rng.f64();
            (
                self.inner.cfg.base_latency
                    + Duration::from_nanos(jit as u64)
                    + Duration::from_nanos(extra_ns),
                rng.coin((self.inner.cfg.drop_prob + extra_drop).min(1.0)),
            )
        };
        if dropped {
            self.inner.stats.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        self.inner
            .stats
            .bytes
            .fetch_add(payload.wire_bytes(), Ordering::Relaxed);
        self.inner.stats.sent.fetch_add(1, Ordering::Relaxed);
        let env = Envelope {
            from,
            to,
            deliver_at: Instant::now() + latency,
            seq: self.inner.seq.fetch_add(1, Ordering::Relaxed),
            payload,
        };
        let inbox = {
            let inboxes = self.inner.inboxes.read().unwrap();
            inboxes[to as usize].clone()
        };
        inbox.q.lock().unwrap().push(env);
        inbox.cv.notify_one();
        true
    }

    /// Receive the next deliverable message for `node`, waiting up to
    /// `timeout`. Respects simulated delivery times.
    pub fn recv_timeout(&self, node: NodeId, timeout: Duration) -> Option<Envelope> {
        if self.is_dead(node) {
            return None;
        }
        let inbox = {
            let inboxes = self.inner.inboxes.read().unwrap();
            inboxes.get(node as usize)?.clone()
        };
        let deadline = Instant::now() + timeout;
        let mut q = inbox.q.lock().unwrap();
        loop {
            let now = Instant::now();
            if let Some(head) = q.peek() {
                if head.deliver_at <= now {
                    return q.pop();
                }
                let wait = head.deliver_at.min(deadline).saturating_duration_since(now);
                if now >= deadline {
                    return None;
                }
                let (guard, _) = inbox.cv.wait_timeout(q, wait).unwrap();
                q = guard;
            } else {
                if now >= deadline {
                    return None;
                }
                let (guard, res) = inbox
                    .cv
                    .wait_timeout(q, deadline.saturating_duration_since(now))
                    .unwrap();
                q = guard;
                if res.timed_out() && q.is_empty() {
                    return None;
                }
            }
            if self.is_dead(node) {
                return None;
            }
        }
    }

    /// Chaos hook: degrade the transport — every subsequent send pays
    /// `extra_latency` on top of the configured base+jitter and is
    /// dropped with `cfg.drop_prob + extra_drop` (clamped to 1) — until
    /// [`SimNet::clear_degraded`]. Messages already in flight keep their
    /// original delivery times.
    pub fn set_degraded(&self, extra_latency: Duration, extra_drop: f64) {
        self.inner
            .extra_latency_ns
            .store(extra_latency.as_nanos() as u64, Ordering::SeqCst);
        self.inner
            .extra_drop_milli
            .store((extra_drop.clamp(0.0, 1.0) * 1000.0).round() as u64, Ordering::SeqCst);
    }

    /// End a degraded-mode spike: back to the configured latency/loss.
    pub fn clear_degraded(&self) {
        self.inner.extra_latency_ns.store(0, Ordering::SeqCst);
        self.inner.extra_drop_milli.store(0, Ordering::SeqCst);
    }

    /// Is a degraded-mode spike active?
    pub fn is_degraded(&self) -> bool {
        self.inner.extra_latency_ns.load(Ordering::Relaxed) != 0
            || self.inner.extra_drop_milli.load(Ordering::Relaxed) != 0
    }

    /// Drain everything currently deliverable without waiting.
    pub fn drain_ready(&self, node: NodeId) -> Vec<Envelope> {
        let mut out = Vec::new();
        while let Some(e) = self.recv_timeout(node, Duration::ZERO) {
            out.push(e);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_in_latency_order() {
        let net = SimNet::new(
            2,
            NetConfig {
                base_latency: Duration::from_millis(1),
                jitter: Duration::ZERO,
                drop_prob: 0.0,
                seed: 1,
            },
        );
        assert!(net.send(0, 1, Payload::Heartbeat));
        let got = net.recv_timeout(1, Duration::from_millis(100));
        assert!(got.is_some());
        assert_eq!(got.unwrap().from, 0);
    }

    #[test]
    fn latency_actually_delays() {
        let net = SimNet::new(
            2,
            NetConfig {
                base_latency: Duration::from_millis(20),
                jitter: Duration::ZERO,
                drop_prob: 0.0,
                seed: 2,
            },
        );
        net.send(0, 1, Payload::Heartbeat);
        // Immediately: not deliverable yet.
        assert!(net.recv_timeout(1, Duration::ZERO).is_none());
        // After the latency: deliverable.
        assert!(net.recv_timeout(1, Duration::from_millis(200)).is_some());
    }

    #[test]
    fn drop_injection_loses_messages() {
        let net = SimNet::new(
            2,
            NetConfig {
                base_latency: Duration::ZERO,
                jitter: Duration::ZERO,
                drop_prob: 0.5,
                seed: 3,
            },
        );
        let mut delivered = 0;
        for _ in 0..1000 {
            if net.send(0, 1, Payload::Heartbeat) {
                delivered += 1;
            }
        }
        assert!((300..700).contains(&delivered), "delivered {delivered}");
        let (sent, dropped, _, _) = net.stats();
        assert_eq!(sent + dropped, 1000);
    }

    #[test]
    fn dead_nodes_refuse_traffic() {
        let net = SimNet::new(3, NetConfig::default());
        net.kill(1);
        assert!(!net.send(0, 1, Payload::Heartbeat));
        assert!(net.is_dead(1));
        assert!(net.recv_timeout(1, Duration::from_millis(5)).is_none());
        let (_, _, dead_letters, _) = net.stats();
        assert_eq!(dead_letters, 1);
    }

    #[test]
    fn add_node_extends_topology() {
        let net = SimNet::new(1, NetConfig::default());
        let n = net.add_node();
        assert_eq!(n, 1);
        assert_eq!(net.len(), 2);
        net.send(0, n, Payload::Heartbeat);
        assert!(net.recv_timeout(n, Duration::from_millis(100)).is_some());
    }

    #[test]
    fn degraded_mode_spikes_latency_and_loss_until_cleared() {
        let net = SimNet::new(
            2,
            NetConfig {
                base_latency: Duration::ZERO,
                jitter: Duration::ZERO,
                drop_prob: 0.0,
                seed: 9,
            },
        );
        // Latency spike: a zero-latency net suddenly delays delivery.
        net.set_degraded(Duration::from_millis(20), 0.0);
        assert!(net.is_degraded());
        net.send(0, 1, Payload::Heartbeat);
        assert!(net.recv_timeout(1, Duration::ZERO).is_none());
        assert!(net.recv_timeout(1, Duration::from_millis(500)).is_some());
        // Loss spike: extra drop probability 1.0 loses everything.
        net.set_degraded(Duration::ZERO, 1.0);
        assert!(!net.send(0, 1, Payload::Heartbeat));
        // Cleared: back to the configured lossless transport.
        net.clear_degraded();
        assert!(!net.is_degraded());
        assert!(net.send(0, 1, Payload::Heartbeat));
    }

    #[test]
    fn cross_thread_delivery() {
        let net = SimNet::new(
            2,
            NetConfig {
                base_latency: Duration::from_micros(100),
                jitter: Duration::from_micros(100),
                drop_prob: 0.0,
                seed: 4,
            },
        );
        let net2 = net.clone();
        let h = std::thread::spawn(move || {
            let mut got = 0;
            while got < 100 {
                if net2.recv_timeout(1, Duration::from_millis(500)).is_some() {
                    got += 1;
                } else {
                    break;
                }
            }
            got
        });
        for _ in 0..100 {
            net.send(0, 1, Payload::Heartbeat);
        }
        assert_eq!(h.join().unwrap(), 100);
    }
}
