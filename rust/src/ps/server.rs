//! Server group: the nodes that own the globally-shared statistics.
//!
//! Each logical server *slot* owns a ring partition of `(matrix, word)`
//! keys. A slot is bound to a physical node (thread); on failure the
//! manager freezes the system (§5.4 "we freeze the whole system until the
//! server manager reschedules a new node"), binds the slot to a fresh node
//! that restores the most recent snapshot, and thaws. Only the failed
//! slot rolls back — the paper's relaxed failover.
//!
//! Servers apply pushed row deltas, answer pulls, run the optional
//! **on-demand projection** (Algorithm 3) against every touched row, emit
//! heartbeats and write barrier-free snapshots.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use super::msg::{Control, NodeId, Payload, RowBatch, RowData};
use super::network::SimNet;
use super::ring::{Ring, SharedRing};
use super::snapshot::{self, SnapshotMeta, Store};
use crate::projection::ondemand::OnDemandProjection;
use crate::sampler::counts::HybridRow;

/// Server-group configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// Logical server slots.
    pub n_servers: usize,
    /// Virtual ring points per slot.
    pub vnodes: usize,
    /// Row width `K` (all shared matrices are K-wide).
    pub row_width: usize,
    /// Barrier-free snapshot cadence (None disables).
    pub snapshot_every: Option<Duration>,
    /// Snapshot directory.
    pub snapshot_dir: Option<PathBuf>,
    /// Algorithm-3 on-demand projection hook.
    pub projection: Option<Arc<OnDemandProjection>>,
    /// Heartbeat cadence to the manager.
    pub heartbeat_every: Duration,
    /// How long a slot may go silent before the manager declares it lost.
    /// Keep generous on oversubscribed hosts — explicit kills are always
    /// detected immediately regardless of this value.
    pub liveness_timeout: Duration,
    /// Hyperparameter + ring metadata stamped into every snapshot (the
    /// `slot` field is overwritten per server node at write time). With
    /// `meta.tables` set, snapshots carry the v3 table-statistics section
    /// the PDP/HDP serving families require.
    pub meta: SnapshotMeta,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_servers: 2,
            vnodes: 64,
            row_width: 0,
            snapshot_every: None,
            snapshot_dir: None,
            projection: None,
            heartbeat_every: Duration::from_millis(25),
            liveness_timeout: Duration::from_secs(5),
            meta: SnapshotMeta::default(),
        }
    }
}

/// Shared statistics of one server thread, surfaced for tests/metrics.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Push messages applied.
    pub pushes: AtomicU64,
    /// Rows folded in.
    pub rows_applied: AtomicU64,
    /// Pull requests answered.
    pub pulls: AtomicU64,
    /// Projection corrections performed (Algorithm 3).
    pub corrections: AtomicU64,
    /// Snapshots written.
    pub snapshots: AtomicU64,
}

struct ServerNode {
    net: SimNet,
    id: NodeId,
    slot: usize,
    manager: NodeId,
    cfg: ServerConfig,
    store: Store,
    stats: Arc<ServerStats>,
    /// Group-wide shutdown flag — a replacement node spawned *during*
    /// shutdown would otherwise never receive its Terminate.
    shutdown: Arc<AtomicBool>,
    /// Segment bookkeeping for v4 incremental session checkpoints:
    /// which keys changed/drained since the last seal and which
    /// immutable segments the last manifest referenced. The live
    /// `store` is the memtable. Periodic cadence and shutdown snapshots
    /// keep writing full v3 dumps (one self-compacting file).
    seglog: snapshot::SegmentLog,
    /// Outcome of the most recent seal, keyed by checkpoint epoch — a
    /// retried `SnapshotReq` re-acks this instead of resealing, so a
    /// duplicate request (or duplicate ack delivery) can never count a
    /// slot that failed to serialize as checkpointed.
    last_seal: Option<(u64, bool)>,
}

impl ServerNode {
    fn snapshot_path(cfg: &ServerConfig, slot: usize) -> Option<PathBuf> {
        cfg.snapshot_dir
            .as_ref()
            .map(|d| d.join(snapshot::slot_snapshot_name(slot)))
    }

    fn run(mut self) {
        let mut last_heartbeat = Instant::now();
        let mut last_snapshot = Instant::now();
        loop {
            if self.net.is_dead(self.id) {
                return;
            }
            if self.shutdown.load(Ordering::Relaxed) {
                self.write_snapshot();
                return;
            }
            if last_heartbeat.elapsed() >= self.cfg.heartbeat_every {
                self.net.send(self.id, self.manager, Payload::Heartbeat);
                last_heartbeat = Instant::now();
            }
            if let Some(every) = self.cfg.snapshot_every {
                if last_snapshot.elapsed() >= every {
                    self.write_snapshot();
                    last_snapshot = Instant::now();
                }
            }
            let env = match self.net.recv_timeout(self.id, Duration::from_millis(5)) {
                Some(e) => e,
                None => continue,
            };
            match env.payload {
                Payload::Push { matrix, rows } => {
                    self.stats.pushes.fetch_add(1, Ordering::Relaxed);
                    for (word, delta) in rows {
                        // Sparse and dense delta rows fold in identically;
                        // the store row grows to whichever width the
                        // incoming encoding implies.
                        let width = self.cfg.row_width.max(delta.min_width());
                        let row = self
                            .store
                            .entry((matrix, word))
                            .or_insert_with(|| HybridRow::new(width));
                        row.ensure_width(width);
                        row.fold_rowdata(&delta);
                        self.seglog.mark_dirty((matrix, word));
                        self.stats.rows_applied.fetch_add(1, Ordering::Relaxed);
                        if let Some(p) = &self.cfg.projection {
                            let n = p.correct(&mut self.store, matrix, word);
                            self.stats.corrections.fetch_add(n, Ordering::Relaxed);
                        }
                    }
                }
                Payload::PullReq {
                    matrix,
                    words,
                    req_id,
                } => {
                    self.stats.pulls.fetch_add(1, Ordering::Relaxed);
                    let rows: Vec<(u32, RowData)> = words
                        .into_iter()
                        .map(|w| {
                            // Absolute rows ship in the cheaper encoding
                            // too; a never-touched row is an empty sparse
                            // row (all zeros, ~9 bytes on the wire).
                            let row = match self.store.get(&(matrix, w)) {
                                Some(row) => row.to_rowdata(),
                                None => RowData::Sparse(Vec::new()),
                            };
                            (w, row)
                        })
                        .collect();
                    self.net.send(
                        self.id,
                        env.from,
                        Payload::PullResp {
                            matrix,
                            rows,
                            req_id,
                        },
                    );
                }
                Payload::SnapshotReq { dir, epoch } => {
                    // Session checkpoint: seal the delta accumulated since
                    // the last checkpoint into the segment log (v4
                    // manifest + immutable segments, carrying unchanged
                    // segments by hardlink) instead of dumping the whole
                    // store. Idempotent per epoch: a retried request
                    // re-acks the recorded outcome rather than resealing.
                    let ok = match self.last_seal {
                        Some((e, ok)) if e == epoch => ok,
                        _ => {
                            let mut meta = self.cfg.meta.clone();
                            meta.slot = self.slot as u32;
                            let ok = self.seglog.seal_to(&dir, &self.store, &meta).is_ok();
                            if ok {
                                self.stats.snapshots.fetch_add(1, Ordering::Relaxed);
                            }
                            self.last_seal = Some((epoch, ok));
                            ok
                        }
                    };
                    self.net.send(
                        self.id,
                        env.from,
                        Payload::SnapshotAck {
                            slot: self.slot as u32,
                            ok,
                            dir,
                            epoch,
                        },
                    );
                }
                Payload::HandoffReq {
                    new_slots,
                    vnodes,
                    dest,
                    dest_slot,
                } => {
                    // Elastic grow: re-shard this slot's store under the
                    // grown ring (rebuilt locally — it is a pure function
                    // of `(slots, vnodes)`), ship every row the new
                    // geometry routes to `dest_slot`, and report the
                    // accounting to the controller.
                    let grown = Ring::new(new_slots as usize, vnodes as usize);
                    let total = self.store.len() as u64;
                    let keys: Vec<(u8, u32)> = self
                        .store
                        .keys()
                        .filter(|&&(m, w)| grown.route(m, w) == dest_slot)
                        .copied()
                        .collect();
                    let moved = keys.len() as u64;
                    let mut by_matrix: std::collections::HashMap<u8, RowBatch> =
                        std::collections::HashMap::new();
                    for key in keys {
                        if let Some(row) = self.store.remove(&key) {
                            self.seglog.mark_removed(key);
                            by_matrix
                                .entry(key.0)
                                .or_default()
                                .push((key.1, row.to_rowdata()));
                        }
                    }
                    for (matrix, rows) in by_matrix {
                        self.net.send(
                            self.id,
                            dest,
                            Payload::Handoff {
                                matrix,
                                rows,
                                ack_to: env.from,
                            },
                        );
                    }
                    // Snapshots written from here on record the grown
                    // geometry (the serving merge validates slot routing
                    // against it).
                    self.cfg.meta.n_servers = new_slots;
                    self.net.send(
                        self.id,
                        env.from,
                        Payload::HandoffAck {
                            slot: self.slot as u32,
                            moved,
                            total,
                        },
                    );
                }
                Payload::Handoff {
                    matrix,
                    rows,
                    ack_to,
                } => {
                    // Rows arriving from a draining slot are absolute
                    // values for keys this node now owns — install them
                    // verbatim and receipt the batch.
                    let received = rows.len() as u64;
                    for (word, data) in rows {
                        let width = self.cfg.row_width.max(data.min_width());
                        self.store
                            .insert((matrix, word), HybridRow::from_rowdata(&data, width));
                        self.seglog.mark_dirty((matrix, word));
                        self.stats.rows_applied.fetch_add(1, Ordering::Relaxed);
                    }
                    self.net.send(
                        self.id,
                        ack_to,
                        Payload::HandoffAck {
                            slot: self.slot as u32,
                            moved: received,
                            total: 0,
                        },
                    );
                }
                Payload::Control(Control::Kill) => return,
                Payload::Control(Control::Terminate) => {
                    self.write_snapshot();
                    return;
                }
                _ => {}
            }
        }
    }

    fn write_snapshot(&mut self) {
        if let Some(path) = Self::snapshot_path(&self.cfg, self.slot) {
            self.write_snapshot_to(&path);
        }
    }

    fn write_snapshot_to(&mut self, path: &std::path::Path) -> bool {
        let mut meta = self.cfg.meta.clone();
        meta.slot = self.slot as u32;
        let bytes = snapshot::encode_store_meta(&self.store, &meta);
        let ok = snapshot::write_atomic(path, &bytes).is_ok();
        if ok {
            self.stats.snapshots.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }
}

/// Handle to the running server group: the ring, the slot→node binding,
/// the freeze flag, and the manager thread.
pub struct ServerGroup {
    /// The consistent-hash ring over slots — shared with every client so
    /// an elastic grow ([`ServerGroup::grow`]) re-routes live traffic.
    pub ring: SharedRing,
    /// Current slot → physical node binding (failover rebinds entries,
    /// a grow appends the new slot's node).
    pub slots: Arc<RwLock<Vec<NodeId>>>,
    /// System-wide freeze flag (server failover / membership change in
    /// progress).
    pub frozen: Arc<AtomicBool>,
    /// Per-slot stats handles (index = slot; follows the *current* node).
    pub stats: Arc<RwLock<Vec<Arc<ServerStats>>>>,
    /// Manager node id.
    pub manager_id: NodeId,
    /// Shared with the manager thread so failover replacements spawned
    /// after a grow carry the grown geometry in their snapshot meta.
    cfg: Arc<RwLock<ServerConfig>>,
    net: SimNet,
    shutdown: Arc<AtomicBool>,
    manager_handle: Option<std::thread::JoinHandle<()>>,
    server_handles: Arc<std::sync::Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

/// Accounting returned by [`ServerGroup::grow`]: drain reports from every
/// pre-existing slot plus arrival receipts from the new slot. Consistent
/// hashing bounds `rows_moved / rows_total` at ≈`1/(N+1)` — the property
/// the chaos scenarios assert.
#[derive(Clone, Copy, Debug, Default)]
pub struct HandoffStats {
    /// Rows the draining slots shipped to the new slot.
    pub rows_moved: u64,
    /// Rows the draining slots owned before the drain.
    pub rows_total: u64,
    /// Rows the new slot receipted as installed.
    pub rows_received: u64,
    /// Every drain reported and every shipped row was receipted.
    pub complete: bool,
}

impl HandoffStats {
    /// Fraction of owned rows that moved (≈`1/(N+1)` for an N→N+1 grow).
    pub fn moved_fraction(&self) -> f64 {
        if self.rows_total == 0 {
            0.0
        } else {
            self.rows_moved as f64 / self.rows_total as f64
        }
    }
}

/// A cloneable elastic-membership handle, detached from the owning
/// [`ServerGroup`]: every field is shared state, so a chaos-injection
/// thread can grow the ring *while* the training loop holds the group
/// (and the session) on another thread.
#[derive(Clone)]
pub struct Elastic {
    ring: SharedRing,
    slots: Arc<RwLock<Vec<NodeId>>>,
    frozen: Arc<AtomicBool>,
    stats: Arc<RwLock<Vec<Arc<ServerStats>>>>,
    manager_id: NodeId,
    cfg: Arc<RwLock<ServerConfig>>,
    net: SimNet,
    shutdown: Arc<AtomicBool>,
    server_handles: Arc<std::sync::Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Elastic {
    /// Current number of logical slots.
    pub fn n_slots(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    /// The physical node currently bound to `slot`.
    pub fn slot_node(&self, slot: usize) -> NodeId {
        self.slots.read().unwrap()[slot]
    }

    /// Kill the node behind `slot` (fault injection); the manager's
    /// heartbeat tracking detects the loss and fails the slot over.
    pub fn kill_slot(&self, slot: usize) {
        let node = self.slots.read().unwrap()[slot];
        self.net.kill(node);
    }

    /// Grow the ring `N → N+1` under load (elastic membership): freeze
    /// client traffic, spawn the new slot's node, have every existing
    /// slot drain-and-handoff the rows the grown ring assigns to the new
    /// slot ([`Payload::HandoffReq`] → [`Payload::Handoff`] →
    /// [`Payload::HandoffAck`]), publish the grown ring to live clients,
    /// and thaw. Consistent hashing guarantees keys only ever move *to*
    /// the new slot, so ≈`1/(N+1)` of owned rows travel — the returned
    /// [`HandoffStats`] carries the exact accounting.
    pub fn grow(&self) -> HandoffStats {
        let (old_n, vnodes, new_cfg) = {
            let mut cfg = self.cfg.write().unwrap();
            let old_n = cfg.n_servers;
            cfg.n_servers += 1;
            cfg.meta.n_servers = cfg.n_servers as u32;
            (old_n, cfg.vnodes, cfg.clone())
        };
        let new_n = old_n + 1;
        // Freeze pushes/pulls (clients spin in `wait_unfrozen`) while
        // ownership moves — the same protocol failover uses — and give
        // in-flight client traffic a moment to land on the servers.
        self.frozen.store(true, Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(50));

        // Spawn the new slot's node with an empty store.
        let new_id = self.net.add_node();
        let st = Arc::new(ServerStats::default());
        let node = ServerNode {
            net: self.net.clone(),
            id: new_id,
            slot: old_n,
            manager: self.manager_id,
            cfg: new_cfg,
            store: Store::new(),
            stats: st.clone(),
            shutdown: self.shutdown.clone(),
            // A grow-spawned slot has no segment history: its first
            // checkpoint seal writes a fresh full base.
            seglog: snapshot::SegmentLog::new(old_n as u32),
            last_seal: None,
        };
        self.server_handles
            .lock()
            .unwrap()
            .push(std::thread::spawn(move || node.run()));
        self.slots.write().unwrap().push(new_id);
        self.stats.write().unwrap().push(st);

        // Drain-and-handoff from every pre-existing slot, accounted at a
        // throwaway controller endpoint.
        let ctl = self.net.add_node();
        let targets: Vec<NodeId> = self.slots.read().unwrap()[..old_n].to_vec();
        for &node in &targets {
            self.net.send(
                ctl,
                node,
                Payload::HandoffReq {
                    new_slots: new_n as u32,
                    vnodes: vnodes as u32,
                    dest: new_id,
                    dest_slot: old_n as u32,
                },
            );
        }
        let mut out = HandoffStats::default();
        let mut drains = 0usize;
        let deadline = Instant::now() + Duration::from_secs(10);
        while (drains < old_n || out.rows_received < out.rows_moved)
            && Instant::now() < deadline
        {
            if let Some(env) = self.net.recv_timeout(ctl, Duration::from_millis(20)) {
                if let Payload::HandoffAck { slot, moved, total } = env.payload {
                    if slot as usize == old_n {
                        out.rows_received += moved;
                    } else {
                        drains += 1;
                        out.rows_moved += moved;
                        out.rows_total += total;
                    }
                }
            }
        }
        out.complete = drains == old_n && out.rows_received == out.rows_moved;

        // Publish the grown ring — live clients route with it on their
        // next send — then thaw.
        *self.ring.write().unwrap() = Ring::new(new_n, vnodes);
        self.frozen.store(false, Ordering::SeqCst);
        out
    }
}

impl ServerGroup {
    /// Spawn `cfg.n_servers` server nodes plus the server manager.
    /// `net` must already contain a node id for the manager and each
    /// server; they are allocated here via [`SimNet::add_node`].
    pub fn spawn(net: &SimNet, cfg: ServerConfig) -> ServerGroup {
        Self::spawn_with_stores(net, cfg, Vec::new())
    }

    /// [`spawn`](Self::spawn), seeding slot `i` with `initial[i]` — the
    /// session-resume path: a checkpointed run's slot stores continue
    /// exactly where they left off. Missing entries start empty.
    pub fn spawn_with_stores(
        net: &SimNet,
        cfg: ServerConfig,
        mut initial: Vec<Store>,
    ) -> ServerGroup {
        initial.resize_with(cfg.n_servers, Store::new);
        let manager_id = net.add_node();
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut slot_ids = Vec::with_capacity(cfg.n_servers);
        let mut stats = Vec::with_capacity(cfg.n_servers);
        let handles = Arc::new(std::sync::Mutex::new(Vec::new()));
        for (slot, store) in initial.into_iter().enumerate() {
            let id = net.add_node();
            let st = Arc::new(ServerStats::default());
            let node = ServerNode {
                net: net.clone(),
                id,
                slot,
                manager: manager_id,
                cfg: cfg.clone(),
                store,
                stats: st.clone(),
                shutdown: shutdown.clone(),
                seglog: snapshot::SegmentLog::new(slot as u32),
                last_seal: None,
            };
            handles
                .lock()
                .unwrap()
                .push(std::thread::spawn(move || node.run()));
            slot_ids.push(id);
            stats.push(st);
        }
        let slots = Arc::new(RwLock::new(slot_ids));
        let stats = Arc::new(RwLock::new(stats));
        let frozen = Arc::new(AtomicBool::new(false));
        let ring = Arc::new(RwLock::new(Ring::new(cfg.n_servers, cfg.vnodes)));
        let cfg = Arc::new(RwLock::new(cfg));

        // The server manager: liveness tracking + slot failover (§5.4).
        let manager_handle = {
            let net = net.clone();
            let slots = slots.clone();
            let stats = stats.clone();
            let frozen = frozen.clone();
            let shutdown = shutdown.clone();
            let cfg = cfg.clone();
            let handles = handles.clone();
            std::thread::spawn(move || {
                let mut last_seen: Vec<Instant> =
                    vec![Instant::now(); slots.read().unwrap().len()];
                loop {
                    if shutdown.load(Ordering::Relaxed) {
                        return;
                    }
                    // An elastic grow appends slots at runtime — start
                    // tracking their liveness as they appear.
                    while last_seen.len() < slots.read().unwrap().len() {
                        last_seen.push(Instant::now());
                    }
                    // Drain heartbeats.
                    while let Some(env) = net.recv_timeout(manager_id, Duration::from_millis(2)) {
                        if let Payload::Heartbeat = env.payload {
                            let slot_of = {
                                let s = slots.read().unwrap();
                                s.iter().position(|&id| id == env.from)
                            };
                            if let Some(slot) = slot_of {
                                if slot < last_seen.len() {
                                    last_seen[slot] = Instant::now();
                                }
                            }
                        }
                    }
                    // Failover: a slot whose node is dead (or silent far
                    // beyond the heartbeat cadence) gets a fresh node.
                    for slot in 0..last_seen.len() {
                        let node = slots.read().unwrap()[slot];
                        let liveness = cfg.read().unwrap().liveness_timeout;
                        let lost = net.is_dead(node)
                            || last_seen[slot].elapsed() > liveness;
                        if !lost {
                            continue;
                        }
                        let cfg = cfg.read().unwrap().clone();
                        // Make sure the old binding can't keep serving
                        // (a merely-slow node would split the slot).
                        net.kill(node);
                        // Freeze the whole system (paper §5.4).
                        frozen.store(true, Ordering::SeqCst);
                        let new_id = net.add_node();
                        // Restore from the most recent snapshot in any
                        // format (cadence snapshots are full v3 dumps;
                        // a checkpoint dir may hold a v4 manifest).
                        let store = cfg
                            .snapshot_dir
                            .as_ref()
                            .and_then(|d| {
                                snapshot::load_slot_file(
                                    d,
                                    &snapshot::slot_snapshot_name(slot),
                                )
                                .ok()
                            })
                            .map(|(_, store, _)| store)
                            .unwrap_or_default();
                        let st = Arc::new(ServerStats::default());
                        let node = ServerNode {
                            net: net.clone(),
                            id: new_id,
                            slot,
                            manager: manager_id,
                            cfg,
                            store,
                            stats: st.clone(),
                            shutdown: shutdown.clone(),
                            // The replacement restarts segment history:
                            // its first seal writes a fresh full base.
                            seglog: snapshot::SegmentLog::new(slot as u32),
                            last_seal: None,
                        };
                        handles
                            .lock()
                            .unwrap()
                            .push(std::thread::spawn(move || node.run()));
                        slots.write().unwrap()[slot] = new_id;
                        stats.write().unwrap()[slot] = st;
                        last_seen[slot] = Instant::now();
                        frozen.store(false, Ordering::SeqCst);
                    }
                }
            })
        };

        ServerGroup {
            ring,
            slots,
            frozen,
            stats,
            manager_id,
            cfg,
            net: net.clone(),
            shutdown,
            manager_handle: Some(manager_handle),
            server_handles: handles,
        }
    }

    /// A detached, cloneable [`Elastic`] membership handle over this
    /// group's shared state — grow/kill the ring from other threads
    /// (chaos injection) while the group itself stays owned here.
    pub fn elastic(&self) -> Elastic {
        Elastic {
            ring: self.ring.clone(),
            slots: self.slots.clone(),
            frozen: self.frozen.clone(),
            stats: self.stats.clone(),
            manager_id: self.manager_id,
            cfg: self.cfg.clone(),
            net: self.net.clone(),
            shutdown: self.shutdown.clone(),
            server_handles: self.server_handles.clone(),
        }
    }

    /// Grow the ring `N → N+1` under load — see [`Elastic::grow`].
    pub fn grow(&self) -> HandoffStats {
        self.elastic().grow()
    }

    /// Resolve the physical node currently bound to a slot.
    pub fn node_for_slot(&self, slot: u32) -> NodeId {
        self.slots.read().unwrap()[slot as usize]
    }

    /// Kill the physical node behind `slot` (failure injection). The
    /// manager will detect and fail over.
    pub fn kill_slot(&self, slot: usize) {
        let node = self.slots.read().unwrap()[slot];
        self.net.kill(node);
    }

    /// Sum of a stat across current slots.
    pub fn total_corrections(&self) -> u64 {
        self.stats
            .read()
            .unwrap()
            .iter()
            .map(|s| s.corrections.load(Ordering::Relaxed))
            .sum()
    }

    /// Stop all servers and the manager.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for &node in self.slots.read().unwrap().iter() {
            self.net
                .send(self.manager_id, node, Payload::Control(Control::Terminate));
        }
        if let Some(h) = self.manager_handle.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = std::mem::take(&mut *self.server_handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        let _ = &self.cfg;
    }
}

impl Drop for ServerGroup {
    fn drop(&mut self) {
        if self.manager_handle.is_some() {
            self.shutdown_inner();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::network::NetConfig;

    fn fast_net() -> SimNet {
        SimNet::new(
            0,
            NetConfig {
                base_latency: Duration::from_micros(50),
                jitter: Duration::from_micros(50),
                drop_prob: 0.0,
                seed: 1,
            },
        )
    }

    fn pull(
        net: &SimNet,
        me: NodeId,
        server: NodeId,
        matrix: u8,
        words: Vec<u32>,
    ) -> Vec<(u32, RowData)> {
        net.send(me, server, Payload::PullReq { matrix, words, req_id: 1 });
        loop {
            let env = net
                .recv_timeout(me, Duration::from_secs(2))
                .expect("pull timed out");
            if let Payload::PullResp { rows, .. } = env.payload {
                return rows;
            }
        }
    }

    #[test]
    fn push_then_pull_roundtrip() {
        let net = fast_net();
        let me = net.add_node();
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: 2,
                row_width: 4,
                ..Default::default()
            },
        );
        let slot = group.ring.read().unwrap().route(0, 7);
        let server = group.node_for_slot(slot);
        net.send(
            me,
            server,
            Payload::Push {
                matrix: 0,
                rows: vec![(7, RowData::Dense(vec![1, 2, 3, 4].into()))],
            },
        );
        // Mixed encodings must aggregate identically.
        net.send(
            me,
            server,
            Payload::Push {
                matrix: 0,
                rows: vec![(7, RowData::Sparse(vec![(0, 1), (3, -1)]))],
            },
        );
        // Eventual: give the server a moment, then pull.
        std::thread::sleep(Duration::from_millis(30));
        let rows = pull(&net, me, server, 0, vec![7, 8]);
        assert_eq!(&*rows[0].1.to_dense(4), &[2, 2, 3, 3]);
        assert_eq!(&*rows[1].1.to_dense(4), &[0, 0, 0, 0], "unknown rows pull as zeros");
        group.shutdown();
    }

    #[test]
    fn deltas_from_multiple_clients_aggregate() {
        let net = fast_net();
        let a = net.add_node();
        let b = net.add_node();
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: 1,
                row_width: 2,
                ..Default::default()
            },
        );
        let server = group.node_for_slot(0);
        for _ in 0..10 {
            net.send(a, server, Payload::Push { matrix: 0, rows: vec![(1, RowData::Sparse(vec![(0, 1)]))] });
            net.send(b, server, Payload::Push { matrix: 0, rows: vec![(1, RowData::Sparse(vec![(1, 1)]))] });
        }
        std::thread::sleep(Duration::from_millis(50));
        let rows = pull(&net, a, server, 0, vec![1]);
        assert_eq!(&*rows[0].1.to_dense(2), &[10, 10]);
        group.shutdown();
    }

    /// Session support: slots spawn pre-seeded with a resumed store, and
    /// a `SnapshotReq` checkpoints the live store into any directory,
    /// acknowledged to the requester.
    #[test]
    fn seeded_stores_and_on_demand_checkpoint() {
        let net = fast_net();
        let me = net.add_node();
        let mut s0 = Store::new();
        s0.insert((0, 2), vec![9, 1].into());
        let group = ServerGroup::spawn_with_stores(
            &net,
            ServerConfig {
                n_servers: 1,
                row_width: 2,
                meta: SnapshotMeta {
                    model: "AliasLDA".into(),
                    k: 2,
                    run_id: 0x5E55,
                    ..Default::default()
                },
                ..Default::default()
            },
            vec![s0.clone()],
        );
        let server = group.node_for_slot(0);
        // The seeded state answers pulls with no pushes ever applied.
        let rows = pull(&net, me, server, 0, vec![2]);
        assert_eq!(&*rows[0].1.to_dense(2), &[9, 1], "seeded store lost");
        // On-demand checkpoint into an arbitrary directory.
        let dir =
            std::env::temp_dir().join(format!("hplvm_ckpt_req_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        net.send(me, server, Payload::SnapshotReq { dir: dir.clone(), epoch: 1 });
        let acked = loop {
            let env = net
                .recv_timeout(me, Duration::from_secs(2))
                .expect("checkpoint ack timed out");
            if let Payload::SnapshotAck { slot, ok, dir: acked_dir, epoch } = env.payload {
                assert_eq!(acked_dir, dir, "ack must echo the checkpoint dir");
                assert_eq!(epoch, 1, "ack must echo the checkpoint epoch");
                break (slot, ok);
            }
        };
        assert_eq!(acked, (0, true));
        // The slot file is a v4 manifest: the legacy full-dump reader
        // refuses it, the directory-aware loader replays it exactly.
        let bytes = snapshot::read_snapshot(&dir.join(snapshot::slot_snapshot_name(0)))
            .expect("checkpoint file missing");
        assert!(
            snapshot::decode_store_meta(&bytes).is_none(),
            "a v4 manifest must not decode as a pre-v4 full dump"
        );
        let (meta, store, generation) =
            snapshot::load_slot_file(&dir, &snapshot::slot_snapshot_name(0)).unwrap();
        assert_eq!(store, s0);
        assert_eq!(generation, 1, "first seal writes base generation 1");
        assert_eq!(meta.unwrap().run_id, 0x5E55, "run id must stamp checkpoints");
        // A retried request in the same epoch re-acks the recorded
        // outcome without resealing.
        net.send(me, server, Payload::SnapshotReq { dir: dir.clone(), epoch: 1 });
        loop {
            let env = net
                .recv_timeout(me, Duration::from_secs(2))
                .expect("retry ack timed out");
            if let Payload::SnapshotAck { ok, epoch, .. } = env.payload {
                assert!(ok);
                assert_eq!(epoch, 1);
                break;
            }
        }
        let seals = group.stats.read().unwrap()[0]
            .snapshots
            .load(Ordering::Relaxed);
        assert_eq!(seals, 1, "retried request must not reseal");
        group.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Elastic grow: push rows across 2 slots, grow to 3, and verify the
    /// handoff accounting (≈1/3 of rows move, all receipted) plus that
    /// every row is still pullable from its new owner under the grown
    /// ring.
    #[test]
    fn grow_hands_off_exactly_the_new_slots_rows() {
        let net = fast_net();
        let me = net.add_node();
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: 2,
                row_width: 2,
                ..Default::default()
            },
        );
        let vocab = 600u32;
        for w in 0..vocab {
            let slot = group.ring.read().unwrap().route(0, w);
            let server = group.node_for_slot(slot);
            net.send(
                me,
                server,
                Payload::Push {
                    matrix: 0,
                    rows: vec![(w, RowData::Sparse(vec![(0, w as i32 + 1)]))],
                },
            );
        }
        std::thread::sleep(Duration::from_millis(60));

        let stats = group.grow();
        assert!(stats.complete, "handoff did not settle: {stats:?}");
        assert_eq!(stats.rows_total, vocab as u64, "every pushed row counted");
        assert_eq!(stats.rows_received, stats.rows_moved, "receipts must match");
        let frac = stats.moved_fraction();
        let expect = 1.0 / 3.0;
        assert!(
            frac > 0.35 * expect && frac < 2.5 * expect,
            "moved fraction {frac:.3} vs expected ≈{expect:.3}"
        );
        assert_eq!(group.ring.read().unwrap().slots(), 3);
        assert!(!group.frozen.load(Ordering::SeqCst), "must thaw after grow");

        // Every row is served by its (possibly new) owner, value intact.
        for w in (0..vocab).step_by(7) {
            let slot = group.ring.read().unwrap().route(0, w);
            let server = group.node_for_slot(slot);
            let rows = pull(&net, me, server, 0, vec![w]);
            assert_eq!(
                rows[0].1.to_dense(2)[0],
                w as i32 + 1,
                "row {w} lost in handoff (slot {slot})"
            );
        }
        group.shutdown();
    }

    #[test]
    fn server_failover_restores_from_snapshot() {
        let dir = std::env::temp_dir().join(format!("hplvm_failover_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let net = fast_net();
        let me = net.add_node();
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: 1,
                row_width: 2,
                snapshot_every: Some(Duration::from_millis(20)),
                snapshot_dir: Some(dir.clone()),
                heartbeat_every: Duration::from_millis(5),
                ..Default::default()
            },
        );
        let old_node = group.node_for_slot(0);
        net.send(me, old_node, Payload::Push { matrix: 0, rows: vec![(3, RowData::Dense(vec![5, 7].into()))] });
        // Wait for at least one snapshot.
        std::thread::sleep(Duration::from_millis(120));
        group.kill_slot(0);
        // Manager must detect, spawn a replacement, rebind the slot.
        let mut new_node = old_node;
        for _ in 0..100 {
            std::thread::sleep(Duration::from_millis(20));
            new_node = group.node_for_slot(0);
            if new_node != old_node {
                break;
            }
        }
        assert_ne!(new_node, old_node, "failover never happened");
        assert!(!group.frozen.load(Ordering::SeqCst), "must thaw after failover");
        let rows = pull(&net, me, new_node, 0, vec![3]);
        assert_eq!(&*rows[0].1.to_dense(2), &[5, 7], "snapshot state lost in failover");
        group.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
