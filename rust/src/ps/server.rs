//! Server group: the nodes that own the globally-shared statistics.
//!
//! Each logical server *slot* owns a ring partition of `(matrix, word)`
//! keys. A slot is bound to a physical node (thread); on failure the
//! manager freezes the system (§5.4 "we freeze the whole system until the
//! server manager reschedules a new node"), binds the slot to a fresh node
//! that restores the most recent snapshot, and thaws. Only the failed
//! slot rolls back — the paper's relaxed failover.
//!
//! Servers apply pushed row deltas, answer pulls, run the optional
//! **on-demand projection** (Algorithm 3) against every touched row, emit
//! heartbeats and write barrier-free snapshots.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use super::msg::{Control, NodeId, Payload, RowData};
use super::network::SimNet;
use super::ring::Ring;
use super::snapshot::{self, SnapshotMeta, Store};
use crate::projection::ondemand::OnDemandProjection;

/// Server-group configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// Logical server slots.
    pub n_servers: usize,
    /// Virtual ring points per slot.
    pub vnodes: usize,
    /// Row width `K` (all shared matrices are K-wide).
    pub row_width: usize,
    /// Barrier-free snapshot cadence (None disables).
    pub snapshot_every: Option<Duration>,
    /// Snapshot directory.
    pub snapshot_dir: Option<PathBuf>,
    /// Algorithm-3 on-demand projection hook.
    pub projection: Option<Arc<OnDemandProjection>>,
    /// Heartbeat cadence to the manager.
    pub heartbeat_every: Duration,
    /// How long a slot may go silent before the manager declares it lost.
    /// Keep generous on oversubscribed hosts — explicit kills are always
    /// detected immediately regardless of this value.
    pub liveness_timeout: Duration,
    /// Hyperparameter + ring metadata stamped into every snapshot (the
    /// `slot` field is overwritten per server node at write time). With
    /// `meta.tables` set, snapshots carry the v3 table-statistics section
    /// the PDP/HDP serving families require.
    pub meta: SnapshotMeta,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_servers: 2,
            vnodes: 64,
            row_width: 0,
            snapshot_every: None,
            snapshot_dir: None,
            projection: None,
            heartbeat_every: Duration::from_millis(25),
            liveness_timeout: Duration::from_secs(5),
            meta: SnapshotMeta::default(),
        }
    }
}

/// Shared statistics of one server thread, surfaced for tests/metrics.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Push messages applied.
    pub pushes: AtomicU64,
    /// Rows folded in.
    pub rows_applied: AtomicU64,
    /// Pull requests answered.
    pub pulls: AtomicU64,
    /// Projection corrections performed (Algorithm 3).
    pub corrections: AtomicU64,
    /// Snapshots written.
    pub snapshots: AtomicU64,
}

struct ServerNode {
    net: SimNet,
    id: NodeId,
    slot: usize,
    manager: NodeId,
    cfg: ServerConfig,
    store: Store,
    stats: Arc<ServerStats>,
    /// Group-wide shutdown flag — a replacement node spawned *during*
    /// shutdown would otherwise never receive its Terminate.
    shutdown: Arc<AtomicBool>,
}

impl ServerNode {
    fn snapshot_path(cfg: &ServerConfig, slot: usize) -> Option<PathBuf> {
        cfg.snapshot_dir
            .as_ref()
            .map(|d| d.join(snapshot::slot_snapshot_name(slot)))
    }

    fn run(mut self) {
        let mut last_heartbeat = Instant::now();
        let mut last_snapshot = Instant::now();
        loop {
            if self.net.is_dead(self.id) {
                return;
            }
            if self.shutdown.load(Ordering::Relaxed) {
                self.write_snapshot();
                return;
            }
            if last_heartbeat.elapsed() >= self.cfg.heartbeat_every {
                self.net.send(self.id, self.manager, Payload::Heartbeat);
                last_heartbeat = Instant::now();
            }
            if let Some(every) = self.cfg.snapshot_every {
                if last_snapshot.elapsed() >= every {
                    self.write_snapshot();
                    last_snapshot = Instant::now();
                }
            }
            let env = match self.net.recv_timeout(self.id, Duration::from_millis(5)) {
                Some(e) => e,
                None => continue,
            };
            match env.payload {
                Payload::Push { matrix, rows } => {
                    self.stats.pushes.fetch_add(1, Ordering::Relaxed);
                    for (word, delta) in rows {
                        // Sparse and dense delta rows fold in identically;
                        // the store row grows to whichever width the
                        // incoming encoding implies.
                        let width = self.cfg.row_width.max(delta.min_width());
                        let row = self
                            .store
                            .entry((matrix, word))
                            .or_insert_with(|| vec![0i32; width]);
                        if row.len() < width {
                            row.resize(width, 0);
                        }
                        delta.fold_saturating_into(row);
                        self.stats.rows_applied.fetch_add(1, Ordering::Relaxed);
                        if let Some(p) = &self.cfg.projection {
                            let n = p.correct(&mut self.store, matrix, word);
                            self.stats.corrections.fetch_add(n, Ordering::Relaxed);
                        }
                    }
                }
                Payload::PullReq {
                    matrix,
                    words,
                    req_id,
                } => {
                    self.stats.pulls.fetch_add(1, Ordering::Relaxed);
                    let rows: Vec<(u32, RowData)> = words
                        .into_iter()
                        .map(|w| {
                            // Absolute rows ship in the cheaper encoding
                            // too; a never-touched row is an empty sparse
                            // row (all zeros, ~9 bytes on the wire).
                            let row = match self.store.get(&(matrix, w)) {
                                Some(row) => RowData::from_dense_auto(row),
                                None => RowData::Sparse(Vec::new()),
                            };
                            (w, row)
                        })
                        .collect();
                    self.net.send(
                        self.id,
                        env.from,
                        Payload::PullResp {
                            matrix,
                            rows,
                            req_id,
                        },
                    );
                }
                Payload::SnapshotReq { dir } => {
                    // Session checkpoint: write this slot's store into the
                    // requested directory and acknowledge (echoing the
                    // directory — the requester's dedup key). Idempotent:
                    // a retried request rewrites the same bytes atomically.
                    let path = dir.join(snapshot::slot_snapshot_name(self.slot));
                    let ok = self.write_snapshot_to(&path);
                    self.net.send(
                        self.id,
                        env.from,
                        Payload::SnapshotAck {
                            slot: self.slot as u32,
                            ok,
                            dir,
                        },
                    );
                }
                Payload::Control(Control::Kill) => return,
                Payload::Control(Control::Terminate) => {
                    self.write_snapshot();
                    return;
                }
                _ => {}
            }
        }
    }

    fn write_snapshot(&mut self) {
        if let Some(path) = Self::snapshot_path(&self.cfg, self.slot) {
            self.write_snapshot_to(&path);
        }
    }

    fn write_snapshot_to(&mut self, path: &std::path::Path) -> bool {
        let mut meta = self.cfg.meta.clone();
        meta.slot = self.slot as u32;
        let bytes = snapshot::encode_store_meta(&self.store, &meta);
        let ok = snapshot::write_atomic(path, &bytes).is_ok();
        if ok {
            self.stats.snapshots.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }
}

/// Handle to the running server group: the ring, the slot→node binding,
/// the freeze flag, and the manager thread.
pub struct ServerGroup {
    /// The consistent-hash ring over slots.
    pub ring: Ring,
    /// Current slot → physical node binding (failover rebinds entries).
    pub slots: Arc<RwLock<Vec<NodeId>>>,
    /// System-wide freeze flag (server failover in progress).
    pub frozen: Arc<AtomicBool>,
    /// Per-slot stats handles (index = slot; follows the *current* node).
    pub stats: Arc<RwLock<Vec<Arc<ServerStats>>>>,
    /// Manager node id.
    pub manager_id: NodeId,
    cfg: ServerConfig,
    net: SimNet,
    shutdown: Arc<AtomicBool>,
    manager_handle: Option<std::thread::JoinHandle<()>>,
    server_handles: Arc<std::sync::Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl ServerGroup {
    /// Spawn `cfg.n_servers` server nodes plus the server manager.
    /// `net` must already contain a node id for the manager and each
    /// server; they are allocated here via [`SimNet::add_node`].
    pub fn spawn(net: &SimNet, cfg: ServerConfig) -> ServerGroup {
        Self::spawn_with_stores(net, cfg, Vec::new())
    }

    /// [`spawn`](Self::spawn), seeding slot `i` with `initial[i]` — the
    /// session-resume path: a checkpointed run's slot stores continue
    /// exactly where they left off. Missing entries start empty.
    pub fn spawn_with_stores(
        net: &SimNet,
        cfg: ServerConfig,
        mut initial: Vec<Store>,
    ) -> ServerGroup {
        initial.resize_with(cfg.n_servers, Store::new);
        let manager_id = net.add_node();
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut slot_ids = Vec::with_capacity(cfg.n_servers);
        let mut stats = Vec::with_capacity(cfg.n_servers);
        let handles = Arc::new(std::sync::Mutex::new(Vec::new()));
        for (slot, store) in initial.into_iter().enumerate() {
            let id = net.add_node();
            let st = Arc::new(ServerStats::default());
            let node = ServerNode {
                net: net.clone(),
                id,
                slot,
                manager: manager_id,
                cfg: cfg.clone(),
                store,
                stats: st.clone(),
                shutdown: shutdown.clone(),
            };
            handles
                .lock()
                .unwrap()
                .push(std::thread::spawn(move || node.run()));
            slot_ids.push(id);
            stats.push(st);
        }
        let slots = Arc::new(RwLock::new(slot_ids));
        let stats = Arc::new(RwLock::new(stats));
        let frozen = Arc::new(AtomicBool::new(false));

        // The server manager: liveness tracking + slot failover (§5.4).
        let manager_handle = {
            let net = net.clone();
            let slots = slots.clone();
            let stats = stats.clone();
            let frozen = frozen.clone();
            let shutdown = shutdown.clone();
            let cfg = cfg.clone();
            let handles = handles.clone();
            std::thread::spawn(move || {
                let mut last_seen: Vec<Instant> =
                    vec![Instant::now(); slots.read().unwrap().len()];
                loop {
                    if shutdown.load(Ordering::Relaxed) {
                        return;
                    }
                    // Drain heartbeats.
                    while let Some(env) = net.recv_timeout(manager_id, Duration::from_millis(2)) {
                        if let Payload::Heartbeat = env.payload {
                            let slot_of = {
                                let s = slots.read().unwrap();
                                s.iter().position(|&id| id == env.from)
                            };
                            if let Some(slot) = slot_of {
                                last_seen[slot] = Instant::now();
                            }
                        }
                    }
                    // Failover: a slot whose node is dead (or silent far
                    // beyond the heartbeat cadence) gets a fresh node.
                    for slot in 0..last_seen.len() {
                        let node = slots.read().unwrap()[slot];
                        let lost = net.is_dead(node)
                            || last_seen[slot].elapsed() > cfg.liveness_timeout;
                        if !lost {
                            continue;
                        }
                        // Make sure the old binding can't keep serving
                        // (a merely-slow node would split the slot).
                        net.kill(node);
                        // Freeze the whole system (paper §5.4).
                        frozen.store(true, Ordering::SeqCst);
                        let new_id = net.add_node();
                        let store = ServerNode::snapshot_path(&cfg, slot)
                            .and_then(|p| snapshot::read_snapshot(&p))
                            .and_then(|b| snapshot::decode_store(&b))
                            .unwrap_or_default();
                        let st = Arc::new(ServerStats::default());
                        let node = ServerNode {
                            net: net.clone(),
                            id: new_id,
                            slot,
                            manager: manager_id,
                            cfg: cfg.clone(),
                            store,
                            stats: st.clone(),
                            shutdown: shutdown.clone(),
                        };
                        handles
                            .lock()
                            .unwrap()
                            .push(std::thread::spawn(move || node.run()));
                        slots.write().unwrap()[slot] = new_id;
                        stats.write().unwrap()[slot] = st;
                        last_seen[slot] = Instant::now();
                        frozen.store(false, Ordering::SeqCst);
                    }
                }
            })
        };

        ServerGroup {
            ring: Ring::new(cfg.n_servers, cfg.vnodes),
            slots,
            frozen,
            stats,
            manager_id,
            cfg,
            net: net.clone(),
            shutdown,
            manager_handle: Some(manager_handle),
            server_handles: handles,
        }
    }

    /// Resolve the physical node currently bound to a slot.
    pub fn node_for_slot(&self, slot: u32) -> NodeId {
        self.slots.read().unwrap()[slot as usize]
    }

    /// Kill the physical node behind `slot` (failure injection). The
    /// manager will detect and fail over.
    pub fn kill_slot(&self, slot: usize) {
        let node = self.slots.read().unwrap()[slot];
        self.net.kill(node);
    }

    /// Sum of a stat across current slots.
    pub fn total_corrections(&self) -> u64 {
        self.stats
            .read()
            .unwrap()
            .iter()
            .map(|s| s.corrections.load(Ordering::Relaxed))
            .sum()
    }

    /// Stop all servers and the manager.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for &node in self.slots.read().unwrap().iter() {
            self.net
                .send(self.manager_id, node, Payload::Control(Control::Terminate));
        }
        if let Some(h) = self.manager_handle.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = std::mem::take(&mut *self.server_handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        let _ = &self.cfg;
    }
}

impl Drop for ServerGroup {
    fn drop(&mut self) {
        if self.manager_handle.is_some() {
            self.shutdown_inner();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::network::NetConfig;

    fn fast_net() -> SimNet {
        SimNet::new(
            0,
            NetConfig {
                base_latency: Duration::from_micros(50),
                jitter: Duration::from_micros(50),
                drop_prob: 0.0,
                seed: 1,
            },
        )
    }

    fn pull(
        net: &SimNet,
        me: NodeId,
        server: NodeId,
        matrix: u8,
        words: Vec<u32>,
    ) -> Vec<(u32, RowData)> {
        net.send(me, server, Payload::PullReq { matrix, words, req_id: 1 });
        loop {
            let env = net
                .recv_timeout(me, Duration::from_secs(2))
                .expect("pull timed out");
            if let Payload::PullResp { rows, .. } = env.payload {
                return rows;
            }
        }
    }

    #[test]
    fn push_then_pull_roundtrip() {
        let net = fast_net();
        let me = net.add_node();
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: 2,
                row_width: 4,
                ..Default::default()
            },
        );
        let slot = group.ring.route(0, 7);
        let server = group.node_for_slot(slot);
        net.send(
            me,
            server,
            Payload::Push {
                matrix: 0,
                rows: vec![(7, RowData::Dense(vec![1, 2, 3, 4].into()))],
            },
        );
        // Mixed encodings must aggregate identically.
        net.send(
            me,
            server,
            Payload::Push {
                matrix: 0,
                rows: vec![(7, RowData::Sparse(vec![(0, 1), (3, -1)]))],
            },
        );
        // Eventual: give the server a moment, then pull.
        std::thread::sleep(Duration::from_millis(30));
        let rows = pull(&net, me, server, 0, vec![7, 8]);
        assert_eq!(&*rows[0].1.to_dense(4), &[2, 2, 3, 3]);
        assert_eq!(&*rows[1].1.to_dense(4), &[0, 0, 0, 0], "unknown rows pull as zeros");
        group.shutdown();
    }

    #[test]
    fn deltas_from_multiple_clients_aggregate() {
        let net = fast_net();
        let a = net.add_node();
        let b = net.add_node();
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: 1,
                row_width: 2,
                ..Default::default()
            },
        );
        let server = group.node_for_slot(0);
        for _ in 0..10 {
            net.send(a, server, Payload::Push { matrix: 0, rows: vec![(1, RowData::Sparse(vec![(0, 1)]))] });
            net.send(b, server, Payload::Push { matrix: 0, rows: vec![(1, RowData::Sparse(vec![(1, 1)]))] });
        }
        std::thread::sleep(Duration::from_millis(50));
        let rows = pull(&net, a, server, 0, vec![1]);
        assert_eq!(&*rows[0].1.to_dense(2), &[10, 10]);
        group.shutdown();
    }

    /// Session support: slots spawn pre-seeded with a resumed store, and
    /// a `SnapshotReq` checkpoints the live store into any directory,
    /// acknowledged to the requester.
    #[test]
    fn seeded_stores_and_on_demand_checkpoint() {
        let net = fast_net();
        let me = net.add_node();
        let mut s0 = Store::new();
        s0.insert((0, 2), vec![9, 1]);
        let group = ServerGroup::spawn_with_stores(
            &net,
            ServerConfig {
                n_servers: 1,
                row_width: 2,
                meta: SnapshotMeta {
                    model: "AliasLDA".into(),
                    k: 2,
                    run_id: 0x5E55,
                    ..Default::default()
                },
                ..Default::default()
            },
            vec![s0.clone()],
        );
        let server = group.node_for_slot(0);
        // The seeded state answers pulls with no pushes ever applied.
        let rows = pull(&net, me, server, 0, vec![2]);
        assert_eq!(&*rows[0].1.to_dense(2), &[9, 1], "seeded store lost");
        // On-demand checkpoint into an arbitrary directory.
        let dir =
            std::env::temp_dir().join(format!("hplvm_ckpt_req_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        net.send(me, server, Payload::SnapshotReq { dir: dir.clone() });
        let acked = loop {
            let env = net
                .recv_timeout(me, Duration::from_secs(2))
                .expect("checkpoint ack timed out");
            if let Payload::SnapshotAck { slot, ok, dir: acked_dir } = env.payload {
                assert_eq!(acked_dir, dir, "ack must echo the checkpoint dir");
                break (slot, ok);
            }
        };
        assert_eq!(acked, (0, true));
        let bytes = snapshot::read_snapshot(&dir.join(snapshot::slot_snapshot_name(0)))
            .expect("checkpoint file missing");
        let (meta, store) = snapshot::decode_store_meta(&bytes).unwrap();
        assert_eq!(store, s0);
        assert_eq!(meta.unwrap().run_id, 0x5E55, "run id must stamp checkpoints");
        group.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn server_failover_restores_from_snapshot() {
        let dir = std::env::temp_dir().join(format!("hplvm_failover_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let net = fast_net();
        let me = net.add_node();
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: 1,
                row_width: 2,
                snapshot_every: Some(Duration::from_millis(20)),
                snapshot_dir: Some(dir.clone()),
                heartbeat_every: Duration::from_millis(5),
                ..Default::default()
            },
        );
        let old_node = group.node_for_slot(0);
        net.send(me, old_node, Payload::Push { matrix: 0, rows: vec![(3, RowData::Dense(vec![5, 7].into()))] });
        // Wait for at least one snapshot.
        std::thread::sleep(Duration::from_millis(120));
        group.kill_slot(0);
        // Manager must detect, spawn a replacement, rebind the slot.
        let mut new_node = old_node;
        for _ in 0..100 {
            std::thread::sleep(Duration::from_millis(20));
            new_node = group.node_for_slot(0);
            if new_node != old_node {
                break;
            }
        }
        assert_ne!(new_node, old_node, "failover never happened");
        assert!(!group.frozen.load(Ordering::SeqCst), "must thaw after failover");
        let rows = pull(&net, me, new_node, 0, vec![3]);
        assert_eq!(&*rows[0].1.to_dense(2), &[5, 7], "snapshot state lost in failover");
        group.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
