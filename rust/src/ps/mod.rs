//! The parameter-server substrate (§4, §5.2–5.4).
//!
//! A faithful in-process rebuild of the third-generation parameter server
//! the paper runs on: a **server group** holding globally-shared
//! `(key → row)` statistics partitioned by a Chord-style consistent-hash
//! ring, **client groups** that push row *deltas* and pull fresh rows
//! asynchronously (eventual consistency), **user-defined communication
//! filters**, a **server manager** (liveness + partition reassignment) and
//! a **scheduler** (progress tracking, straggler policy, the 90%
//! completion rule).
//!
//! Every node is an OS thread; the [`network::SimNet`] transport injects
//! per-message latency, jitter, drops and node kills from a deterministic
//! RNG — the consistency phenomena the paper's techniques respond to
//! (stale reads, conflicting updates, lost deltas after a failover) all
//! arise for real, on the real code paths.

pub mod client;
pub mod filter;
pub mod msg;
pub mod network;
pub mod ring;
pub mod scheduler;
pub mod server;
pub mod snapshot;

pub use client::PsClient;
pub use msg::{Control, Envelope, NodeId, Payload};
pub use network::{NetConfig, SimNet};
pub use ring::{Ring, SharedRing};
pub use scheduler::Scheduler;
pub use server::{Elastic, HandoffStats, ServerConfig, ServerGroup};
