//! Barrier-free snapshots (§5.4).
//!
//! "Clients and servers independently take a snapshot of their memory to
//! disk every N minutes without global barrier." Snapshots are plain
//! binary files written atomically (temp + rename); a replacement node
//! loads the most recent one and continues — rolling only *itself* back,
//! which is the paper's deliberately relaxed failover semantics.
//!
//! Server snapshots carry a [`SnapshotMeta`] header recording the
//! hyperparameters (model, K, α, β) and the ring assignment the store was
//! sharded under — everything the serving layer ([`crate::serve`]) needs
//! to rebuild proposal distributions without the training config.
//!
//! ## Format history
//!
//! * **v1** (`HPLVMSNP`) — bare store, no header. Still decodes, with
//!   `meta = None`.
//! * **v2** (`HPLVMSN2`) — adds the [`SnapshotMeta`] header: model name,
//!   `K`, α, β, vocabulary size, and the ring geometry
//!   (`slot`/`n_servers`/`vnodes`). Still decodes, with
//!   `meta.tables = None`.
//! * **v3** (`HPLVMSN3`) — appends, after the v2 fields: a
//!   `run_id` nonce identifying the producing training run (slot files
//!   from different runs must never merge, even when every configured
//!   hyperparameter matches), then an *optional table-statistics
//!   section*: one `has_tables` byte, followed (when set) by the
//!   [`TableHyper`] triple `(discount, concentration, root)`.
//!   The per-word table **counts** themselves already travel in the store
//!   body as matrix 1 (`s_tw` for PDP; the root `t_k` row for HDP — see
//!   [`crate::coordinator::model::MATRIX_TABLES`]); v3 adds the
//!   hyperparameters that give those counts meaning, which is what the
//!   PDP/HDP serving families need to rebuild the frozen predictive
//!   distributions. LDA snapshots write `has_tables = 0` and are
//!   byte-identical to v2 apart from the magic and that one byte.
//! * **v4** (`HPLVMSN4`, current for *session checkpoints*) — the slot
//!   file becomes an LSM-style **manifest** instead of a full dump: the
//!   same v3 meta fields, then a `generation` watermark and the list of
//!   immutable **segment** files (`HPLVMSEG`, named
//!   `slot{slot}-{gen:06}-{base|delta}.seg`) whose last-writer-wins fold
//!   *is* the store. Cadence and shutdown snapshots still write full v3
//!   dumps (a single self-compacting file); only acknowledged
//!   `checkpoint(dir)` seals segments. Pre-v4 readers refuse a manifest
//!   outright ([`decode_store_meta`] returns `None` — the magic is
//!   unknown to them) rather than mis-decoding it.
//!
//! ## Segment lifecycle (v4)
//!
//! Each server slot's live [`HybridRow`] store is the *memtable* — the
//! authoritative, complete state. A [`SegmentLog`] tracks which keys
//! changed (dirty) or were drained away (tombstones) since the last
//! seal. [`SegmentLog::seal_to`] turns a checkpoint into O(delta) work:
//!
//! 1. carry the previous checkpoint's live segments into the target
//!    directory by hardlink (copy fallback) — no bytes rewritten;
//! 2. seal the dirty keys + tombstones into one new immutable *delta*
//!    segment (absolute rows in [`RowData`] wire form; an empty row is a
//!    tombstone — absent and all-zero are the same state);
//! 3. write the manifest naming the live set, **atomically and last**.
//!
//! The compactor runs *at seal time*: once the live set would exceed a
//! small bound (base + a handful of deltas), the seal writes a fresh
//! full base from the memtable instead — valid because the memtable is
//! by construction exactly fold(sealed segments) + unsealed dirty delta.
//! No background pass, no orphan rewrites, minimal crash surface.
//!
//! Crash consistency: every file is written temp-then-rename, manifest
//! last, so a crash mid-checkpoint leaves at worst *unreferenced*
//! segment files next to the previous (still complete) manifest —
//! readers only open manifest-referenced segments, so orphans (even
//! truncated ones) are inert. Every segment carries a 16-byte footer
//! (`body_len`, FNV-1a checksum); a *referenced* segment that fails the
//! footer check is a hard, named error — never folded silently.
//!
//! Encoders for full dumps always write v3; decoders accept v1–v3 at
//! the byte level and v1–v4 through the directory-aware
//! [`load_slot_file`].
//!
//! Client snapshots have their own two-version history: v1 (shares the
//! `HPLVMSNP` magic) carries shard/iteration/`z`/`r`; v2 (`HPLVMCL2`,
//! current) appends the pulled replica rows in [`RowData`] wire form so
//! a resumed worker starts warm. v1 files still decode (empty replicas).
//!
//! A *session checkpoint* directory additionally carries a
//! [`SessionMeta`] file ([`SESSION_META_NAME`]) next to the slot and
//! client snapshots: run id, completed iteration, RNG epoch, and the
//! config JSON — everything `TrainSession::resume` needs to continue the
//! run in a fresh process under the same `run_id`.

use crate::sampler::counts::{HybridRow, RowData};
use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// A server's store: `(matrix, word) → row`. Rows are [`HybridRow`]s —
/// resident memory scales with each word's occupancy, not `K` — but the
/// on-disk store body is unchanged from the dense era (full-width
/// little-endian cells), so every format version stays bit-compatible.
pub type Store = HashMap<(u8, u32), HybridRow>;

const MAGIC: &[u8; 8] = b"HPLVMSNP";
const MAGIC_V2: &[u8; 8] = b"HPLVMSN2";
const MAGIC_V3: &[u8; 8] = b"HPLVMSN3";
const MAGIC_V4: &[u8; 8] = b"HPLVMSN4";
const MAGIC_SEGMENT: &[u8; 8] = b"HPLVMSEG";

/// Table-side hyperparameters (v3 section) — present for model families
/// whose sufficient statistics include table counts (PDP/HDP).
///
/// The three slots are family-overloaded (a DP is a PDP with discount 0):
///
/// | field           | PDP                  | HDP                       |
/// |-----------------|----------------------|---------------------------|
/// | `discount`      | discount `a`         | `0.0`                     |
/// | `concentration` | concentration `b`    | document-level `b₁`       |
/// | `root`          | word smoothing `γ`   | root concentration `b₀`   |
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TableHyper {
    /// Pitman-Yor discount `a` (0 for the HDP's plain DP).
    pub discount: f64,
    /// Strength of the process the tables belong to (PDP `b`, HDP `b₁`).
    pub concentration: f64,
    /// Root-measure parameter (PDP `γ`, HDP `b₀`).
    pub root: f64,
}

/// Hyperparameters + ring assignment a server store was produced under.
///
/// Written with every v2 store snapshot so a snapshot directory is
/// self-describing: the inference server rebuilds its proposal
/// distributions from `(k, alpha, beta)` and can sanity-check that the
/// slot files it merged really partition the key space (`n_servers`,
/// `vnodes`, `slot`).
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotMeta {
    /// Model display name (e.g. `"AliasLDA"`).
    pub model: String,
    /// Topic count / row width `K`.
    pub k: u32,
    /// Document-topic prior α.
    pub alpha: f64,
    /// Topic-word prior β.
    pub beta: f64,
    /// Vocabulary size the corpus was generated over.
    pub vocab_size: u32,
    /// Ring slot this store belongs to.
    pub slot: u32,
    /// Total logical server slots in the ring.
    pub n_servers: u32,
    /// Virtual ring points per slot.
    pub vnodes: u32,
    /// Training iterations the producing run was *configured* for —
    /// provenance only. The barrier-free design means servers never
    /// observe client progress, so this is not a completed-iteration
    /// count (a mid-run snapshot carries the same value).
    pub iterations: u64,
    /// Per-run nonce (v3): every slot snapshot of one training run
    /// carries the same value, and two runs — even with identical
    /// configuration — carry different ones, so the serving loader can
    /// refuse to merge a directory that mixes runs. 0 for v1/v2 files.
    pub run_id: u64,
    /// v3 table-statistics section: the hyperparameters of the table
    /// counts stored under matrix 1. `None` for LDA snapshots and for
    /// v1/v2 files.
    pub tables: Option<TableHyper>,
}

impl Default for SnapshotMeta {
    fn default() -> Self {
        SnapshotMeta {
            model: String::new(),
            k: 0,
            alpha: 0.0,
            beta: 0.0,
            vocab_size: 0,
            slot: 0,
            n_servers: 1,
            vnodes: 1,
            iterations: 0,
            run_id: 0,
            tables: None,
        }
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}
impl<'a> Reader<'a> {
    fn u32(&mut self) -> Option<u32> {
        let v = u32::from_le_bytes(self.b.get(self.pos..self.pos + 4)?.try_into().ok()?);
        self.pos += 4;
        Some(v)
    }
    fn u64(&mut self) -> Option<u64> {
        let v = u64::from_le_bytes(self.b.get(self.pos..self.pos + 8)?.try_into().ok()?);
        self.pos += 8;
        Some(v)
    }
    fn u8(&mut self) -> Option<u8> {
        let v = *self.b.get(self.pos)?;
        self.pos += 1;
        Some(v)
    }
    fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> Option<String> {
        let len = self.u32()? as usize;
        let bytes = self.b.get(self.pos..self.pos + len)?;
        self.pos += len;
        String::from_utf8(bytes.to_vec()).ok()
    }
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn encode_store_body(buf: &mut Vec<u8>, store: &Store) {
    put_u32(buf, store.len() as u32);
    // Deterministic order for reproducible files.
    let mut keys: Vec<&(u8, u32)> = store.keys().collect();
    keys.sort();
    let mut scratch: Vec<i32> = Vec::new();
    for key in keys {
        let row = &store[key];
        buf.push(key.0);
        put_u32(buf, key.1);
        put_u32(buf, row.k() as u32);
        // Materialize through a reusable scratch row: the body stays the
        // dense-era byte layout regardless of the in-memory form.
        scratch.clear();
        scratch.resize(row.k(), 0);
        row.for_each(|t, v| scratch[t as usize] = v);
        for &v in &scratch {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

fn decode_store_body(r: &mut Reader<'_>) -> Option<Store> {
    let n = r.u32()?;
    let mut store = Store::with_capacity(n as usize);
    for _ in 0..n {
        let matrix = r.u8()?;
        let word = r.u32()?;
        let len = r.u32()? as usize;
        let mut row = Vec::with_capacity(len);
        for _ in 0..len {
            let v = r.u32()? as i32;
            row.push(v);
        }
        // Construct, don't add-diff: cell values (incl. i32::MIN) must
        // land verbatim.
        store.insert((matrix, word), HybridRow::from_dense(&row));
    }
    Some(store)
}

/// Serialize a server store without metadata (legacy v1 format — kept for
/// bit-stable failover tests; new snapshots use [`encode_store_meta`]).
pub fn encode_store(store: &Store) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + store.len() * 32);
    buf.extend_from_slice(MAGIC);
    encode_store_body(&mut buf, store);
    buf
}

fn put_meta_v2_fields(buf: &mut Vec<u8>, meta: &SnapshotMeta) {
    put_str(buf, &meta.model);
    put_u32(buf, meta.k);
    put_f64(buf, meta.alpha);
    put_f64(buf, meta.beta);
    put_u32(buf, meta.vocab_size);
    put_u32(buf, meta.slot);
    put_u32(buf, meta.n_servers);
    put_u32(buf, meta.vnodes);
    put_u64(buf, meta.iterations);
}

/// Serialize a server store with its [`SnapshotMeta`] header (current
/// format, v3).
pub fn encode_store_meta(store: &Store, meta: &SnapshotMeta) -> Vec<u8> {
    let mut buf = Vec::with_capacity(160 + store.len() * 32);
    buf.extend_from_slice(MAGIC_V3);
    put_meta_v2_fields(&mut buf, meta);
    put_u64(&mut buf, meta.run_id);
    match &meta.tables {
        None => buf.push(0),
        Some(t) => {
            buf.push(1);
            put_f64(&mut buf, t.discount);
            put_f64(&mut buf, t.concentration);
            put_f64(&mut buf, t.root);
        }
    }
    encode_store_body(&mut buf, store);
    buf
}

/// Serialize in the legacy v2 layout (no table section). Kept so the
/// backward-compatibility tests can produce genuine v2 bytes; production
/// writers use [`encode_store_meta`]. `meta.tables` is ignored — v2 had
/// nowhere to put it.
pub fn encode_store_meta_v2(store: &Store, meta: &SnapshotMeta) -> Vec<u8> {
    let mut buf = Vec::with_capacity(128 + store.len() * 32);
    buf.extend_from_slice(MAGIC_V2);
    put_meta_v2_fields(&mut buf, meta);
    encode_store_body(&mut buf, store);
    buf
}

/// Parse the magic + metadata header, returning the reader positioned at
/// the store body. Needs only the header bytes — the body may be absent.
fn decode_header(bytes: &[u8]) -> Option<(Option<SnapshotMeta>, Reader<'_>)> {
    if bytes.len() < 12 {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    if &bytes[..8] == MAGIC {
        return Some((None, r));
    }
    let v3 = &bytes[..8] == MAGIC_V3;
    if !v3 && &bytes[..8] != MAGIC_V2 {
        return None;
    }
    let meta = read_meta_fields(&mut r, v3)?;
    Some((Some(meta), r))
}

/// Read the [`SnapshotMeta`] field block shared by v2/v3 headers and the
/// v4 manifest (`with_v3_tail` adds the `run_id` + table section).
fn read_meta_fields(r: &mut Reader<'_>, with_v3_tail: bool) -> Option<SnapshotMeta> {
    let mut meta = SnapshotMeta {
        model: r.str()?,
        k: r.u32()?,
        alpha: r.f64()?,
        beta: r.f64()?,
        vocab_size: r.u32()?,
        slot: r.u32()?,
        n_servers: r.u32()?,
        vnodes: r.u32()?,
        iterations: r.u64()?,
        run_id: 0,
        tables: None,
    };
    if with_v3_tail {
        meta.run_id = r.u64()?;
        meta.tables = match r.u8()? {
            0 => None,
            1 => Some(TableHyper {
                discount: r.f64()?,
                concentration: r.f64()?,
                root: r.f64()?,
            }),
            _ => return None,
        };
    }
    Some(meta)
}

/// Deserialize a server store plus its metadata (`None` for v1 files;
/// `meta.tables = None` for v2 files).
pub fn decode_store_meta(bytes: &[u8]) -> Option<(Option<SnapshotMeta>, Store)> {
    let (meta, mut r) = decode_header(bytes)?;
    Some((meta, decode_store_body(&mut r)?))
}

/// Decode only the metadata header from a byte *prefix* of a snapshot —
/// the store body may be truncated or absent. `Some(None)` = valid v1
/// prefix (no header); `None` = not a snapshot prefix. Understands the
/// v4 manifest header too (the `serve --watch` fingerprint probe must
/// see `run_id` changes regardless of format).
pub fn decode_meta_prefix(bytes: &[u8]) -> Option<Option<SnapshotMeta>> {
    if bytes.len() >= 8 && &bytes[..8] == MAGIC_V4 {
        let mut r = Reader { b: bytes, pos: 8 };
        return read_meta_fields(&mut r, true).map(Some);
    }
    decode_header(bytes).map(|(meta, _)| meta)
}

/// Read just the [`SnapshotMeta`] of a slot file, without loading the
/// store (the header fits comfortably in the first 4 KiB). `None` for
/// missing/corrupt files and headerless v1 files. Cheap enough to poll:
/// the `serve --watch` fingerprint uses the `run_id` this returns to
/// detect same-size same-mtime rewrites.
pub fn read_slot_meta(path: &Path) -> Option<SnapshotMeta> {
    let mut f = std::fs::File::open(path).ok()?;
    let mut buf = [0u8; 4096];
    let mut n = 0;
    loop {
        match f.read(&mut buf[n..]) {
            Ok(0) => break,
            Ok(k) => {
                n += k;
                if n == buf.len() {
                    break;
                }
            }
            Err(_) => return None,
        }
    }
    decode_meta_prefix(&buf[..n])?
}

/// Deserialize a server store (either format), dropping any metadata.
pub fn decode_store(bytes: &[u8]) -> Option<Store> {
    decode_store_meta(bytes).map(|(_, store)| store)
}

/// Canonical server-slot snapshot filename for `slot` — the single
/// source of truth shared by the writer ([`crate::ps::server`]), the
/// loader ([`crate::serve::ServingModel::load_dir`]), and the
/// `serve --watch` poller.
pub fn slot_snapshot_name(slot: usize) -> String {
    format!("server_slot{slot}.snap")
}

/// Does `name` name a server-slot snapshot file?
pub fn is_slot_snapshot_name(name: &str) -> bool {
    name.starts_with("server_slot") && name.ends_with(".snap")
}

/// Write bytes atomically (temp file + rename).
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

/// Read a snapshot file if present and well-formed.
pub fn read_snapshot(path: &Path) -> Option<Vec<u8>> {
    let mut f = std::fs::File::open(path).ok()?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).ok()?;
    Some(buf)
}

// ---------------------------------------------------------------------
// v4: segmented slot snapshots (manifest + immutable segment files)
// ---------------------------------------------------------------------

/// What a segment contains relative to the segments before it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SegmentKind {
    /// A complete dump of the store at its generation — replay starts
    /// here; everything referenced before it is superseded.
    Base,
    /// Only the rows that changed (plus tombstones) since the previous
    /// referenced segment.
    Delta,
}

impl SegmentKind {
    fn to_u8(self) -> u8 {
        match self {
            SegmentKind::Base => 0,
            SegmentKind::Delta => 1,
        }
    }
    fn from_u8(v: u8) -> Option<SegmentKind> {
        match v {
            0 => Some(SegmentKind::Base),
            1 => Some(SegmentKind::Delta),
            _ => None,
        }
    }
}

/// One manifest entry: an immutable segment file in the live set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentRef {
    /// File name next to the manifest (see [`segment_name`]).
    pub name: String,
    /// Base or delta.
    pub kind: SegmentKind,
    /// Seal generation — replay order, strictly increasing.
    pub generation: u64,
    /// Expected byte length of the segment body (file length minus the
    /// 16-byte footer); cross-checked against the footer on load.
    pub body_len: u64,
    /// Expected FNV-1a checksum of the body; ditto.
    pub checksum: u64,
}

/// A v4 slot snapshot: metadata + the live segment set whose in-order
/// fold is the store.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Same self-describing header every v3 dump carries.
    pub meta: SnapshotMeta,
    /// Highest generation among the referenced segments — the watermark
    /// generation-diff reloads compare against. Unchanged by a
    /// checkpoint that sealed nothing new.
    pub generation: u64,
    /// The live set, in replay (generation) order.
    pub segments: Vec<SegmentRef>,
}

/// Canonical segment filename: `slot{slot}-{gen:06}-{base|delta}.seg`.
pub fn segment_name(slot: u32, generation: u64, kind: SegmentKind) -> String {
    let kind = match kind {
        SegmentKind::Base => "base",
        SegmentKind::Delta => "delta",
    };
    format!("slot{slot}-{generation:06}-{kind}.seg")
}

/// Does `name` name a segment file?
pub fn is_segment_name(name: &str) -> bool {
    name.starts_with("slot") && name.ends_with(".seg")
}

/// FNV-1a 64 — the segment footer checksum. Not cryptographic; it
/// detects truncation and bit rot, which is all the torn-checkpoint
/// story needs.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize a v4 manifest.
pub fn encode_manifest(m: &Manifest) -> Vec<u8> {
    let mut buf = Vec::with_capacity(192 + m.segments.len() * 64);
    buf.extend_from_slice(MAGIC_V4);
    put_meta_v2_fields(&mut buf, &m.meta);
    put_u64(&mut buf, m.meta.run_id);
    match &m.meta.tables {
        None => buf.push(0),
        Some(t) => {
            buf.push(1);
            put_f64(&mut buf, t.discount);
            put_f64(&mut buf, t.concentration);
            put_f64(&mut buf, t.root);
        }
    }
    put_u64(&mut buf, m.generation);
    put_u32(&mut buf, m.segments.len() as u32);
    for seg in &m.segments {
        put_str(&mut buf, &seg.name);
        buf.push(seg.kind.to_u8());
        put_u64(&mut buf, seg.generation);
        put_u64(&mut buf, seg.body_len);
        put_u64(&mut buf, seg.checksum);
    }
    buf
}

/// Deserialize a v4 manifest. `None` for anything else (including every
/// pre-v4 format — the caller dispatches on the magic).
pub fn decode_manifest(bytes: &[u8]) -> Option<Manifest> {
    if bytes.len() < 8 || &bytes[..8] != MAGIC_V4 {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    let meta = read_meta_fields(&mut r, true)?;
    let generation = r.u64()?;
    let n = r.u32()? as usize;
    let mut segments = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        segments.push(SegmentRef {
            name: r.str()?,
            kind: SegmentKind::from_u8(r.u8()?)?,
            generation: r.u64()?,
            body_len: r.u64()?,
            checksum: r.u64()?,
        });
    }
    Some(Manifest {
        meta,
        generation,
        segments,
    })
}

/// An empty row is a tombstone: absent and all-zero are the same state
/// (counts are sums of increments; a key with no mass carries no
/// information), so replay removes the key instead of storing a zero
/// row. The rule is uniform across full replay and diff overlay, which
/// is what keeps both paths producing identical stores.
pub fn rowdata_is_tombstone(data: &RowData) -> bool {
    match data {
        RowData::Sparse(es) => es.is_empty(),
        RowData::Dense(r) => r.iter().all(|&v| v == 0),
    }
}

/// Serialize an immutable segment: header, absolute rows in [`RowData`]
/// wire form, and the 16-byte `[body_len][fnv1a]` footer the torn-file
/// detection hangs off.
pub fn encode_segment(
    slot: u32,
    generation: u64,
    kind: SegmentKind,
    rows: &[((u8, u32), RowData)],
) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32 + rows.len() * 24);
    buf.extend_from_slice(MAGIC_SEGMENT);
    put_u32(&mut buf, slot);
    put_u64(&mut buf, generation);
    buf.push(kind.to_u8());
    put_u32(&mut buf, rows.len() as u32);
    for ((matrix, word), data) in rows {
        buf.push(*matrix);
        put_u32(&mut buf, *word);
        put_rowdata(&mut buf, data);
    }
    let body_len = buf.len() as u64;
    let checksum = fnv1a(&buf);
    put_u64(&mut buf, body_len);
    put_u64(&mut buf, checksum);
    buf
}

/// Deserialize a segment, validating the footer *before* trusting any of
/// the body (a truncated or bit-rotted file fails the length or
/// checksum test and returns `None` — it is never partially folded).
#[allow(clippy::type_complexity)]
pub fn decode_segment(bytes: &[u8]) -> Option<(u32, u64, SegmentKind, Vec<((u8, u32), RowData)>)> {
    // magic + slot + gen + kind + count + footer
    if bytes.len() < 8 + 4 + 8 + 1 + 4 + 16 || &bytes[..8] != MAGIC_SEGMENT {
        return None;
    }
    let body_end = bytes.len() - 16;
    let body_len = u64::from_le_bytes(bytes[body_end..body_end + 8].try_into().ok()?);
    let checksum = u64::from_le_bytes(bytes[body_end + 8..].try_into().ok()?);
    if body_len != body_end as u64 || fnv1a(&bytes[..body_end]) != checksum {
        return None;
    }
    let mut r = Reader {
        b: &bytes[..body_end],
        pos: 8,
    };
    let slot = r.u32()?;
    let generation = r.u64()?;
    let kind = SegmentKind::from_u8(r.u8()?)?;
    let n = r.u32()? as usize;
    let mut rows = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        let matrix = r.u8()?;
        let word = r.u32()?;
        rows.push(((matrix, word), read_rowdata(&mut r)?));
    }
    Some((slot, generation, kind, rows))
}

/// Load and validate one manifest-referenced segment. Missing, truncated,
/// or corrupt referenced segments are hard errors naming the file —
/// unlike *unreferenced* leftovers, which loaders never open.
#[allow(clippy::type_complexity)]
pub fn load_segment(dir: &Path, seg: &SegmentRef) -> crate::Result<Vec<((u8, u32), RowData)>> {
    let path = dir.join(&seg.name);
    let bytes = read_snapshot(&path).ok_or_else(|| {
        anyhow::anyhow!(
            "manifest references segment {} but it cannot be read — \
             the checkpoint directory is incomplete",
            path.display()
        )
    })?;
    if bytes.len() < 16 || bytes.len() as u64 != seg.body_len + 16 {
        anyhow::bail!(
            "segment {} is truncated ({} bytes, manifest expects {}) — \
             refusing to fold a torn checkpoint",
            path.display(),
            bytes.len(),
            seg.body_len + 16
        );
    }
    let (_, generation, _, rows) = decode_segment(&bytes).ok_or_else(|| {
        anyhow::anyhow!(
            "segment {} fails its footer length/checksum — \
             refusing to fold a torn checkpoint",
            path.display()
        )
    })?;
    if fnv1a(&bytes[..bytes.len() - 16]) != seg.checksum || generation != seg.generation {
        anyhow::bail!(
            "segment {} does not match its manifest entry (generation/checksum mismatch)",
            path.display()
        );
    }
    Ok(rows)
}

/// Apply one segment's rows onto a store, last-writer-wins, with the
/// empty-row tombstone rule. `k` is the row width the model trains at
/// (rows may carry fewer cells in sparse form).
pub fn apply_segment_rows(store: &mut Store, rows: &[((u8, u32), RowData)], k: u32) {
    for (key, data) in rows {
        if rowdata_is_tombstone(data) {
            store.remove(key);
        } else {
            let width = (k as usize).max(data.min_width());
            store.insert(*key, HybridRow::from_rowdata(data, width));
        }
    }
}

/// Replay a manifest's segments (generation order) into a full store.
pub fn load_manifest_store(dir: &Path, manifest: &Manifest) -> crate::Result<Store> {
    let mut segs: Vec<&SegmentRef> = manifest.segments.iter().collect();
    segs.sort_by_key(|s| s.generation);
    let mut store = Store::new();
    for seg in segs {
        let rows = load_segment(dir, seg)?;
        apply_segment_rows(&mut store, &rows, manifest.meta.k);
    }
    Ok(store)
}

/// Directory-aware slot-snapshot loader: reads `dir/name` in any format
/// v1–v4 and returns `(meta, store, generation)`. Full dumps (v1–v3)
/// load as before with generation 0; a v4 manifest replays its segment
/// set. This is the one entry point session resume, manager failover,
/// and the serving loader share.
pub fn load_slot_file(dir: &Path, name: &str) -> crate::Result<(Option<SnapshotMeta>, Store, u64)> {
    let (meta, store, generation, _) = load_slot_file_tracked(dir, name)?;
    Ok((meta, store, generation))
}

/// [`load_slot_file`], additionally returning the manifest's segment
/// references (`None` for v1–v3 full dumps). The serving layer's
/// generation-diff reload records these as its resident watermark; taking
/// them from the same bytes the store was replayed from keeps the record
/// race-free against a checkpoint landing between two reads of the file.
#[allow(clippy::type_complexity)]
pub fn load_slot_file_tracked(
    dir: &Path,
    name: &str,
) -> crate::Result<(Option<SnapshotMeta>, Store, u64, Option<Vec<SegmentRef>>)> {
    let path = dir.join(name);
    let bytes = read_snapshot(&path)
        .ok_or_else(|| anyhow::anyhow!("cannot read snapshot {}", path.display()))?;
    if bytes.len() >= 8 && &bytes[..8] == MAGIC_V4 {
        let manifest = decode_manifest(&bytes).ok_or_else(|| {
            anyhow::anyhow!("corrupt v4 snapshot manifest {}", path.display())
        })?;
        let store = load_manifest_store(dir, &manifest)?;
        Ok((
            Some(manifest.meta),
            store,
            manifest.generation,
            Some(manifest.segments),
        ))
    } else {
        let (meta, store) = decode_store_meta(&bytes).ok_or_else(|| {
            anyhow::anyhow!("{} is not a slot snapshot in any known format", path.display())
        })?;
        Ok((meta, store, 0, None))
    }
}

/// Read just the manifest of a v4 slot file; `None` for pre-v4 formats
/// or unreadable files. Generation-diff reloads use this to decide how
/// much of the segment set they actually need to open.
pub fn read_manifest(path: &Path) -> Option<Manifest> {
    decode_manifest(&read_snapshot(path)?)
}

/// Live-set bound: base + this many deltas before the seal rebases into
/// a fresh full dump. Small enough that replay stays a handful of file
/// reads; large enough that steady-state checkpoints stay O(delta).
const MAX_LIVE_SEGMENTS: usize = 5;

/// Per-slot segment bookkeeping: which keys changed since the last seal,
/// which were drained away, and which immutable segments the last
/// manifest referenced (so the next seal can carry them by hardlink).
///
/// The live store itself is the memtable; `SegmentLog` never owns row
/// data, only names and dirt.
#[derive(Debug, Default)]
pub struct SegmentLog {
    slot: u32,
    /// Next seal generation (generations are per-slot, strictly
    /// increasing, and only advance when a segment is actually written).
    next_gen: u64,
    /// Live set of the last successful seal, in replay order.
    segments: Vec<SegmentRef>,
    /// Directory that last seal wrote into — the hardlink source.
    last_dir: Option<PathBuf>,
    /// Keys touched (inserted/folded) since the last seal.
    dirty: HashSet<(u8, u32)>,
    /// Keys removed (drained by handoff) since the last seal.
    tombstones: HashSet<(u8, u32)>,
}

impl SegmentLog {
    /// Fresh log for `slot` — first seal writes a full base.
    pub fn new(slot: u32) -> SegmentLog {
        SegmentLog {
            slot,
            next_gen: 1,
            ..SegmentLog::default()
        }
    }

    /// Record a key whose row changed in the live store.
    pub fn mark_dirty(&mut self, key: (u8, u32)) {
        self.tombstones.remove(&key);
        self.dirty.insert(key);
    }

    /// Record a key removed from the live store (ring handoff drain).
    pub fn mark_removed(&mut self, key: (u8, u32)) {
        self.dirty.remove(&key);
        self.tombstones.insert(key);
    }

    /// Pending dirty + tombstoned keys (what the next delta would seal).
    pub fn pending(&self) -> usize {
        self.dirty.len() + self.tombstones.len()
    }

    /// Seal the current state into `dir`: carry the previous live set,
    /// write at most one new segment (delta of the dirt, or a fresh base
    /// when rebasing / starting out / the carry source is gone), then
    /// the manifest — atomically, last. On success the log's live set
    /// points at `dir`; on error nothing is adopted (the previous
    /// checkpoint, if any, is still complete because its manifest was
    /// never overwritten mid-write).
    pub fn seal_to(&mut self, dir: &Path, store: &Store, meta: &SnapshotMeta) -> crate::Result<()> {
        std::fs::create_dir_all(dir)?;
        let rebase = self.segments.len() >= MAX_LIVE_SEGMENTS;
        let carried = if self.segments.is_empty() || rebase {
            None
        } else {
            self.carry_segments(dir)
        };
        let gen = self.next_gen;
        let mut wrote_segment = false;
        let segments = match carried {
            Some(mut segs) => {
                let mut rows: Vec<((u8, u32), RowData)> = Vec::new();
                let mut dirty: Vec<(u8, u32)> = self.dirty.iter().copied().collect();
                dirty.sort_unstable();
                for key in dirty {
                    if let Some(row) = store.get(&key) {
                        rows.push((key, row.to_rowdata()));
                    } else {
                        // Marked dirty but no longer present: tombstone.
                        rows.push((key, RowData::Sparse(Vec::new())));
                    }
                }
                let mut tombs: Vec<(u8, u32)> = self.tombstones.iter().copied().collect();
                tombs.sort_unstable();
                for key in tombs {
                    rows.push((key, RowData::Sparse(Vec::new())));
                }
                if !rows.is_empty() {
                    segs.push(self.write_segment(dir, gen, SegmentKind::Delta, &rows)?);
                    wrote_segment = true;
                }
                segs
            }
            None => {
                // Fresh full base from the memtable (first seal, rebase
                // threshold hit, or the carry source vanished).
                let mut keys: Vec<&(u8, u32)> = store.keys().collect();
                keys.sort();
                let rows: Vec<((u8, u32), RowData)> = keys
                    .into_iter()
                    .map(|&key| (key, store[&key].to_rowdata()))
                    .collect();
                let seg = self.write_segment(dir, gen, SegmentKind::Base, &rows)?;
                wrote_segment = true;
                vec![seg]
            }
        };
        let generation = segments.iter().map(|s| s.generation).max().unwrap_or(0);
        let manifest = Manifest {
            meta: meta.clone(),
            generation,
            segments: segments.clone(),
        };
        write_atomic(
            &dir.join(slot_snapshot_name(self.slot as usize)),
            &encode_manifest(&manifest),
        )?;
        if wrote_segment {
            self.next_gen = gen + 1;
        }
        self.segments = segments;
        self.last_dir = Some(dir.to_path_buf());
        self.dirty.clear();
        self.tombstones.clear();
        Ok(())
    }

    fn write_segment(
        &self,
        dir: &Path,
        generation: u64,
        kind: SegmentKind,
        rows: &[((u8, u32), RowData)],
    ) -> crate::Result<SegmentRef> {
        let name = segment_name(self.slot, generation, kind);
        let bytes = encode_segment(self.slot, generation, kind, rows);
        let body_end = bytes.len() - 16;
        let body_len = body_end as u64;
        let checksum = fnv1a(&bytes[..body_end]);
        write_atomic(&dir.join(&name), &bytes)?;
        Ok(SegmentRef {
            name,
            kind,
            generation,
            body_len,
            checksum,
        })
    }

    /// Bring the previous live set into `dir` by hardlink (copy when
    /// linking fails, e.g. across filesystems). `None` on any failure —
    /// the caller then falls back to a fresh full base, which is always
    /// valid because the live store is complete.
    fn carry_segments(&self, dir: &Path) -> Option<Vec<SegmentRef>> {
        let src_dir = self.last_dir.as_ref()?;
        let mut out = Vec::with_capacity(self.segments.len());
        for seg in &self.segments {
            let src = src_dir.join(&seg.name);
            let dst = dir.join(&seg.name);
            if src != dst && !dst.exists() && std::fs::hard_link(&src, &dst).is_err() {
                std::fs::copy(&src, &dst).ok()?;
            }
            out.push(seg.clone());
        }
        Some(out)
    }
}

const MAGIC_SESSION: &[u8; 8] = b"HPLVMSES";

/// Canonical session-meta filename inside a checkpoint directory.
pub const SESSION_META_NAME: &str = "session.meta";

/// The session-level state of a training-cluster checkpoint: everything
/// [`TrainSession::resume`](crate::coordinator::TrainSession::resume)
/// needs beyond the server slot snapshots and the per-shard client
/// snapshots that sit next to it in the checkpoint directory.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SessionMeta {
    /// The run nonce every slot snapshot of this run carries. A resumed
    /// session keeps training under the same id, so its later snapshots
    /// still pass the serving layer's same-run merge check.
    pub run_id: u64,
    /// Completed iterations at checkpoint time (the resumed session's
    /// next segment starts here).
    pub iteration: u64,
    /// Segment counter — salts the per-segment worker RNG streams so a
    /// resumed run does not replay the randomness of segment 1.
    pub epoch: u64,
    /// The run's global seed.
    pub seed: u64,
    /// The training configuration as [`crate::config::TrainConfig::to_json`]
    /// text (the preset subset — enough to rebuild the topology and, for
    /// synthetic corpora, regenerate the identical corpus).
    pub config_json: String,
    /// Docword file backing the corpus, when trained from a
    /// [`FileSource`](crate::corpus::FileSource); `None` = synthetic.
    pub corpus_file: Option<String>,
    /// Companion vocabulary file of the [`FileSource`], when one widened
    /// the effective vocabulary — resume must rebuild the same `V`.
    ///
    /// [`FileSource`]: crate::corpus::FileSource
    pub vocab_file: Option<String>,
}

/// Serialize a [`SessionMeta`].
pub fn encode_session(m: &SessionMeta) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + m.config_json.len());
    buf.extend_from_slice(MAGIC_SESSION);
    put_u64(&mut buf, m.run_id);
    put_u64(&mut buf, m.iteration);
    put_u64(&mut buf, m.epoch);
    put_u64(&mut buf, m.seed);
    put_str(&mut buf, &m.config_json);
    for path in [&m.corpus_file, &m.vocab_file] {
        match path {
            None => buf.push(0),
            Some(p) => {
                buf.push(1);
                put_str(&mut buf, p);
            }
        }
    }
    buf
}

/// Deserialize a [`SessionMeta`].
pub fn decode_session(bytes: &[u8]) -> Option<SessionMeta> {
    if bytes.len() < 8 || &bytes[..8] != MAGIC_SESSION {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    let run_id = r.u64()?;
    let iteration = r.u64()?;
    let epoch = r.u64()?;
    let seed = r.u64()?;
    let config_json = r.str()?;
    let mut opt_str = || -> Option<Option<String>> {
        match r.u8()? {
            0 => Some(None),
            1 => Some(Some(r.str()?)),
            _ => None,
        }
    };
    let corpus_file = opt_str()?;
    let vocab_file = opt_str()?;
    Some(SessionMeta {
        run_id,
        iteration,
        epoch,
        seed,
        config_json,
        corpus_file,
        vocab_file,
    })
}

const MAGIC_CLIENT_V2: &[u8; 8] = b"HPLVMCL2";

/// A client's resumable state: its shard, completed iterations, all
/// topic assignments (`z`, plus the PDP/HDP table indicators), and —
/// since client-format v2 — the pulled replica rows, so a resumed worker
/// samples against the cluster-wide counts immediately instead of
/// shard-local ones until its first post-resume pull.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientSnapshot {
    /// Shard this client was working.
    pub shard: usize,
    /// Completed iterations.
    pub iteration: u64,
    /// Flattened topic assignments, per document.
    pub z: Vec<Vec<u32>>,
    /// Flattened table indicators, per document (empty for LDA).
    pub r: Vec<Vec<bool>>,
    /// Pulled replica rows at snapshot time, per matrix id
    /// (`(matrix, [(word, row)])`), in wire form. Empty for legacy (v1)
    /// files; restore is then skipped and the first pull warms the
    /// replica as before.
    pub replicas: Vec<(u8, Vec<(u32, RowData)>)>,
}

fn put_rowdata(buf: &mut Vec<u8>, data: &RowData) {
    match data {
        RowData::Dense(r) => {
            buf.push(0);
            put_u32(buf, r.len() as u32);
            for &v in r.iter() {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        RowData::Sparse(es) => {
            buf.push(1);
            put_u32(buf, es.len() as u32);
            for &(t, v) in es {
                put_u32(buf, t);
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
}

fn read_rowdata(r: &mut Reader<'_>) -> Option<RowData> {
    match r.u8()? {
        0 => {
            let len = r.u32()? as usize;
            let mut row = Vec::with_capacity(len);
            for _ in 0..len {
                row.push(r.u32()? as i32);
            }
            Some(RowData::Dense(row.into_boxed_slice()))
        }
        1 => {
            let len = r.u32()? as usize;
            let mut es = Vec::with_capacity(len);
            for _ in 0..len {
                let t = r.u32()?;
                let v = r.u32()? as i32;
                es.push((t, v));
            }
            Some(RowData::Sparse(es))
        }
        _ => None,
    }
}

/// Serialize a client snapshot (current format, v2: appends the replica
/// section after the v1 fields).
pub fn encode_client(s: &ClientSnapshot) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC_CLIENT_V2);
    put_u64(&mut buf, s.shard as u64);
    put_u64(&mut buf, s.iteration);
    put_u32(&mut buf, s.z.len() as u32);
    let empty: Vec<bool> = Vec::new();
    for (i, zd) in s.z.iter().enumerate() {
        let rd = s.r.get(i).unwrap_or(&empty);
        put_u32(&mut buf, zd.len() as u32);
        for &z in zd {
            put_u32(&mut buf, z);
        }
        put_u32(&mut buf, rd.len() as u32);
        let mut bits = vec![0u8; rd.len().div_ceil(8)];
        for (i, &b) in rd.iter().enumerate() {
            if b {
                bits[i / 8] |= 1 << (i % 8);
            }
        }
        buf.extend_from_slice(&bits);
    }
    put_u32(&mut buf, s.replicas.len() as u32);
    for (matrix, rows) in &s.replicas {
        buf.push(*matrix);
        put_u32(&mut buf, rows.len() as u32);
        for (w, data) in rows {
            put_u32(&mut buf, *w);
            put_rowdata(&mut buf, data);
        }
    }
    buf
}

/// Deserialize a client snapshot — current (v2) or legacy (v1, shares
/// the store-v1 magic; decodes with `replicas` empty).
pub fn decode_client(bytes: &[u8]) -> Option<ClientSnapshot> {
    if bytes.len() < 8 {
        return None;
    }
    let v2 = &bytes[..8] == MAGIC_CLIENT_V2;
    if !v2 && &bytes[..8] != MAGIC {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    let shard = r.u64()? as usize;
    let iteration = r.u64()?;
    let ndocs = r.u32()? as usize;
    let mut z = Vec::with_capacity(ndocs);
    let mut rr = Vec::with_capacity(ndocs);
    for _ in 0..ndocs {
        let len = r.u32()? as usize;
        let mut zd = Vec::with_capacity(len);
        for _ in 0..len {
            zd.push(r.u32()?);
        }
        let rlen = r.u32()? as usize;
        let nbytes = rlen.div_ceil(8);
        let mut rd = Vec::with_capacity(rlen);
        let start = r.pos;
        if start + nbytes > r.b.len() {
            return None;
        }
        for i in 0..rlen {
            rd.push(r.b[start + i / 8] & (1 << (i % 8)) != 0);
        }
        r.pos += nbytes;
        z.push(zd);
        rr.push(rd);
    }
    let mut replicas = Vec::new();
    if v2 {
        let nmat = r.u32()? as usize;
        for _ in 0..nmat {
            let matrix = r.u8()?;
            let nrows = r.u32()? as usize;
            let mut rows = Vec::with_capacity(nrows);
            for _ in 0..nrows {
                let w = r.u32()?;
                rows.push((w, read_rowdata(&mut r)?));
            }
            replicas.push((matrix, rows));
        }
    }
    Some(ClientSnapshot {
        shard,
        iteration,
        z,
        r: rr,
        replicas,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip() {
        let mut store = Store::new();
        store.insert((0, 5), vec![1, -2, 3].into());
        store.insert((1, 0), vec![0; 8].into());
        store.insert((0, 1000), vec![i32::MAX, i32::MIN].into());
        let bytes = encode_store(&store);
        let back = decode_store(&bytes).unwrap();
        assert_eq!(store, back);
    }

    #[test]
    fn store_rejects_garbage() {
        assert!(decode_store(b"nonsense").is_none());
        assert!(decode_store(&[]).is_none());
        let mut bytes = encode_store(&Store::new());
        bytes[0] ^= 0xFF;
        assert!(decode_store(&bytes).is_none());
    }

    fn sample_meta() -> SnapshotMeta {
        SnapshotMeta {
            model: "AliasLDA".to_string(),
            k: 20,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 2_000,
            slot: 1,
            n_servers: 2,
            vnodes: 64,
            iterations: 17,
            run_id: 0xDEAD_BEEF,
            tables: None,
        }
    }

    fn sample_meta_tables() -> SnapshotMeta {
        let mut meta = sample_meta();
        meta.model = "AliasPDP".to_string();
        meta.tables = Some(TableHyper {
            discount: 0.1,
            concentration: 10.0,
            root: 0.5,
        });
        meta
    }

    /// Satellite: save → load reproduces counts, hyperparameters, and the
    /// ring assignment bit-for-bit — with and without the v3 table
    /// section.
    #[test]
    fn store_meta_roundtrip_bit_for_bit() {
        let mut store = Store::new();
        store.insert((0, 3), vec![7, 0, -1, 4].into());
        store.insert((1, 0), vec![2; 4].into());
        for meta in [sample_meta(), sample_meta_tables()] {
            let bytes = encode_store_meta(&store, &meta);
            let (meta2, store2) = decode_store_meta(&bytes).unwrap();
            let meta2 = meta2.expect("v3 snapshot must carry metadata");
            assert_eq!(meta2, meta);
            assert_eq!(store2, store);
            // Hyperparameters survive exactly (f64 bit patterns, not text).
            assert_eq!(meta2.alpha.to_bits(), 0.1f64.to_bits());
            assert_eq!(meta2.beta.to_bits(), 0.01f64.to_bits());
            // Encoding is deterministic: same input, same bytes.
            assert_eq!(bytes, encode_store_meta(&store, &meta));
        }
    }

    #[test]
    fn v1_files_decode_with_no_meta() {
        let mut store = Store::new();
        store.insert((0, 9), vec![1, 2].into());
        let bytes = encode_store(&store);
        let (meta, back) = decode_store_meta(&bytes).unwrap();
        assert!(meta.is_none());
        assert_eq!(back, store);
        // And the plain decoder reads every format.
        let v3 = encode_store_meta(&store, &sample_meta_tables());
        assert_eq!(decode_store(&v3).unwrap(), store);
    }

    #[test]
    fn v2_files_decode_with_no_table_section() {
        let mut store = Store::new();
        store.insert((0, 9), vec![1, 2].into());
        store.insert((1, 9), vec![0, 1].into());
        // Encode with the legacy writer: genuine v2 bytes.
        let bytes = encode_store_meta_v2(&store, &sample_meta_tables());
        let (meta, back) = decode_store_meta(&bytes).unwrap();
        let meta = meta.expect("v2 carries a header");
        assert_eq!(meta.model, "AliasPDP");
        assert_eq!(meta.k, 20);
        assert!(meta.tables.is_none(), "v2 has no table section");
        assert_eq!(meta.run_id, 0, "v2 has no run id");
        assert_eq!(back, store);
    }

    #[test]
    fn truncated_v2_and_v3_rejected() {
        for meta in [sample_meta(), sample_meta_tables()] {
            let bytes = encode_store_meta(&Store::new(), &meta);
            for cut in [9, 15, bytes.len() - 1] {
                assert!(
                    decode_store_meta(&bytes[..cut]).is_none(),
                    "truncation at {cut} accepted"
                );
            }
        }
        let v2 = encode_store_meta_v2(&Store::new(), &sample_meta());
        assert!(decode_store_meta(&v2[..v2.len() - 1]).is_none());
    }

    #[test]
    fn meta_prefix_and_slot_meta_read_header_only() {
        let mut store = Store::new();
        for w in 0..50u32 {
            store.insert((0, w), vec![1; 32].into());
        }
        let meta = sample_meta_tables();
        let bytes = encode_store_meta(&store, &meta);
        // A header-sized prefix is enough — the body can be cut off.
        let prefix = &bytes[..256.min(bytes.len())];
        let got = decode_meta_prefix(prefix).unwrap().unwrap();
        assert_eq!(got, meta);
        assert_eq!(got.run_id, 0xDEAD_BEEF);
        // v1 prefixes carry no header; garbage is rejected.
        assert_eq!(decode_meta_prefix(&encode_store(&store)[..16]), Some(None));
        assert!(decode_meta_prefix(b"nonsense----").is_none());

        // File-backed variant (the --watch poller's probe).
        let dir = std::env::temp_dir().join(format!(
            "hplvm_snap_meta_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = dir.join("server_slot0.snap");
        write_atomic(&path, &bytes).unwrap();
        assert_eq!(read_slot_meta(&path).unwrap(), meta);
        assert!(read_slot_meta(&dir.join("missing.snap")).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v3_garbage_table_flag_rejected() {
        let meta = sample_meta();
        let mut bytes = encode_store_meta(&Store::new(), &meta);
        // The has_tables byte sits after the fixed v2 fields + run_id.
        let flag_pos = 8 + 4 + meta.model.len() + 4 + 8 + 8 + 4 + 4 + 4 + 4 + 8 + 8;
        assert_eq!(bytes[flag_pos], 0);
        bytes[flag_pos] = 7;
        assert!(decode_store_meta(&bytes).is_none());
    }

    #[test]
    fn client_roundtrip() {
        let snap = ClientSnapshot {
            shard: 3,
            iteration: 17,
            z: vec![vec![1, 2, 3], vec![], vec![9; 20]],
            r: vec![vec![true, false, true], vec![], vec![false; 20]],
            replicas: vec![
                (
                    0,
                    vec![
                        (4, RowData::Sparse(vec![(0, 2), (7, -1)])),
                        (9, RowData::Dense(vec![1, 0, 3, 0].into_boxed_slice())),
                    ],
                ),
                (1, vec![(4, RowData::Sparse(vec![(2, 5)]))]),
            ],
        };
        let bytes = encode_client(&snap);
        let back = decode_client(&bytes).unwrap();
        assert_eq!(snap, back);
        // Truncations inside the replica section are rejected.
        for cut in [bytes.len() - 1, bytes.len() - 5] {
            assert!(decode_client(&bytes[..cut]).is_none(), "cut {cut}");
        }
    }

    /// Legacy (v1) client snapshots — the old store-v1 magic, no replica
    /// section — still decode, with `replicas` empty.
    #[test]
    fn client_v1_decodes_with_empty_replicas() {
        let snap = ClientSnapshot {
            shard: 1,
            iteration: 5,
            z: vec![vec![2, 0]],
            r: vec![vec![true, true]],
            replicas: vec![(0, vec![(3, RowData::Sparse(vec![(1, 1)]))])],
        };
        // Hand-build the v1 bytes: swap the magic, cut the replica tail.
        let v2 = encode_client(&snap);
        let mut v1 = v2.clone();
        v1[..8].copy_from_slice(MAGIC);
        // The replica section is the suffix after z/r; find it by
        // encoding the same snapshot with no replicas.
        let bare = encode_client(&ClientSnapshot {
            replicas: Vec::new(),
            ..snap.clone()
        });
        v1.truncate(bare.len() - 4); // minus the empty replica count
        let back = decode_client(&v1).unwrap();
        assert_eq!(back.shard, snap.shard);
        assert_eq!(back.z, snap.z);
        assert_eq!(back.r, snap.r);
        assert!(back.replicas.is_empty());
    }

    #[test]
    fn session_meta_roundtrip_and_rejects_garbage() {
        for (corpus_file, vocab_file) in [
            (None, None),
            (Some("data/docword.txt".to_string()), None),
            (
                Some("data/docword.txt".to_string()),
                Some("data/vocab.txt".to_string()),
            ),
        ] {
            let m = SessionMeta {
                run_id: 0xFEED_F00D,
                iteration: 40,
                epoch: 3,
                seed: 42,
                config_json: r#"{"model":"AliasLDA","topics":20}"#.to_string(),
                corpus_file,
                vocab_file,
            };
            let bytes = encode_session(&m);
            assert_eq!(decode_session(&bytes).unwrap(), m);
            // Every truncation is rejected, never mis-decoded.
            for cut in [0, 7, 9, 33, bytes.len() - 1] {
                assert!(decode_session(&bytes[..cut]).is_none(), "cut {cut}");
            }
        }
        assert!(decode_session(b"nonsense----------------").is_none());
        // A store snapshot is not a session meta.
        assert!(decode_session(&encode_store(&Store::new())).is_none());
    }

    #[test]
    fn segment_roundtrip_and_footer_rejects_torn_files() {
        let rows: Vec<((u8, u32), RowData)> = vec![
            ((0, 3), RowData::Sparse(vec![(1, 4), (7, -2)])),
            ((0, 9), RowData::Dense(vec![1, 0, 3, 0].into_boxed_slice())),
            ((1, 3), RowData::Sparse(Vec::new())), // tombstone
        ];
        let bytes = encode_segment(2, 7, SegmentKind::Delta, &rows);
        let (slot, generation, kind, back) = decode_segment(&bytes).unwrap();
        assert_eq!((slot, generation, kind), (2, 7, SegmentKind::Delta));
        assert_eq!(back, rows);
        // Any truncation fails the footer length check; any flipped bit
        // fails the checksum.
        for cut in [0, 8, 20, bytes.len() - 1] {
            assert!(decode_segment(&bytes[..cut]).is_none(), "cut {cut}");
        }
        let mut flipped = bytes.clone();
        flipped[10] ^= 0x40;
        assert!(decode_segment(&flipped).is_none());
    }

    #[test]
    fn manifest_roundtrip_and_pre_v4_readers_refuse() {
        let manifest = Manifest {
            meta: sample_meta_tables(),
            generation: 9,
            segments: vec![
                SegmentRef {
                    name: segment_name(1, 4, SegmentKind::Base),
                    kind: SegmentKind::Base,
                    generation: 4,
                    body_len: 123,
                    checksum: 0xABCD,
                },
                SegmentRef {
                    name: segment_name(1, 9, SegmentKind::Delta),
                    kind: SegmentKind::Delta,
                    generation: 9,
                    body_len: 17,
                    checksum: 0x5A5A,
                },
            ],
        };
        let bytes = encode_manifest(&manifest);
        assert_eq!(decode_manifest(&bytes).unwrap(), manifest);
        // The --watch meta probe reads v4 headers…
        assert_eq!(
            decode_meta_prefix(&bytes).unwrap().unwrap(),
            manifest.meta
        );
        // …but the pre-v4 full-dump reader refuses the unknown magic
        // outright instead of mis-decoding the manifest as a store.
        assert!(decode_store_meta(&bytes).is_none());
        for cut in [7, 12, bytes.len() - 1] {
            assert!(decode_manifest(&bytes[..cut]).is_none(), "cut {cut}");
        }
    }

    #[test]
    fn segment_log_seals_base_then_delta_and_replays_identically() {
        let dir = std::env::temp_dir().join(format!(
            "hplvm_seglog_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let meta = SnapshotMeta {
            k: 4,
            ..sample_meta()
        };
        let mut store = Store::new();
        store.insert((0, 1), vec![1, 0, 2, 0].into());
        store.insert((0, 2), vec![0, 5, 0, 0].into());
        let mut log = SegmentLog::new(meta.slot);
        log.seal_to(&dir, &store, &meta).unwrap();
        let (m1, s1, g1) = load_slot_file(&dir, &slot_snapshot_name(meta.slot as usize)).unwrap();
        assert_eq!(m1.unwrap(), meta);
        assert_eq!(s1, store);
        assert_eq!(g1, 1);

        // Mutate: change one row, drop one, add one — seal a delta.
        store.insert((0, 1), vec![1, 1, 2, 0].into());
        store.remove(&(0, 2));
        store.insert((1, 7), vec![0, 0, 0, 9].into());
        log.mark_dirty((0, 1));
        log.mark_removed((0, 2));
        log.mark_dirty((1, 7));
        log.seal_to(&dir, &store, &meta).unwrap();
        let (_, s2, g2) = load_slot_file(&dir, &slot_snapshot_name(meta.slot as usize)).unwrap();
        assert_eq!(s2, store, "delta replay must reproduce the memtable");
        assert_eq!(g2, 2);
        let manifest =
            read_manifest(&dir.join(slot_snapshot_name(meta.slot as usize))).unwrap();
        assert_eq!(manifest.segments.len(), 2);
        assert_eq!(manifest.segments[0].kind, SegmentKind::Base);
        assert_eq!(manifest.segments[1].kind, SegmentKind::Delta);

        // A no-change seal advances nothing: same generation, no new
        // segment, and the store still replays.
        log.seal_to(&dir, &store, &meta).unwrap();
        let (_, s3, g3) = load_slot_file(&dir, &slot_snapshot_name(meta.slot as usize)).unwrap();
        assert_eq!(s3, store);
        assert_eq!(g3, 2, "no dirt, no new generation");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_log_rebases_at_live_set_bound() {
        let dir = std::env::temp_dir().join(format!(
            "hplvm_seglog_rebase_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let meta = SnapshotMeta {
            k: 2,
            slot: 0,
            ..sample_meta()
        };
        let mut store = Store::new();
        let mut log = SegmentLog::new(0);
        for i in 0..10u32 {
            store.insert((0, i), vec![i as i32 + 1, 0].into());
            log.mark_dirty((0, i));
            log.seal_to(&dir, &store, &meta).unwrap();
            let manifest =
                read_manifest(&dir.join(slot_snapshot_name(0))).unwrap();
            assert!(
                manifest.segments.len() <= MAX_LIVE_SEGMENTS,
                "live set bounded: {} segments after seal {}",
                manifest.segments.len(),
                i
            );
            let (_, loaded, _) = load_slot_file(&dir, &slot_snapshot_name(0)).unwrap();
            assert_eq!(loaded, store, "replay identical after seal {i}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn referenced_truncated_segment_is_a_named_hard_error() {
        let dir = std::env::temp_dir().join(format!(
            "hplvm_seglog_torn_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let meta = SnapshotMeta {
            k: 2,
            slot: 0,
            ..sample_meta()
        };
        let mut store = Store::new();
        store.insert((0, 1), vec![3, 4].into());
        let mut log = SegmentLog::new(0);
        log.seal_to(&dir, &store, &meta).unwrap();
        // Truncate the referenced base segment in place.
        let seg_path = dir.join(segment_name(0, 1, SegmentKind::Base));
        let bytes = std::fs::read(&seg_path).unwrap();
        std::fs::write(&seg_path, &bytes[..bytes.len() - 5]).unwrap();
        let err = load_slot_file(&dir, &slot_snapshot_name(0)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("slot0-000001-base.seg") && msg.contains("torn"),
            "diagnostic must name the segment: {msg}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_write_and_read() {
        let dir = std::env::temp_dir().join(format!("hplvm_snap_test_{}", std::process::id()));
        let path = dir.join("s.snap");
        let mut store = Store::new();
        store.insert((0, 1), vec![42].into());
        write_atomic(&path, &encode_store(&store)).unwrap();
        let bytes = read_snapshot(&path).unwrap();
        assert_eq!(decode_store(&bytes).unwrap(), store);
        std::fs::remove_dir_all(&dir).ok();
    }
}
