//! Barrier-free snapshots (§5.4).
//!
//! "Clients and servers independently take a snapshot of their memory to
//! disk every N minutes without global barrier." Snapshots are plain
//! binary files written atomically (temp + rename); a replacement node
//! loads the most recent one and continues — rolling only *itself* back,
//! which is the paper's deliberately relaxed failover semantics.
//!
//! Server snapshots carry a [`SnapshotMeta`] header recording the
//! hyperparameters (model, K, α, β) and the ring assignment the store was
//! sharded under — everything the serving layer ([`crate::serve`]) needs
//! to rebuild proposal distributions without the training config.
//!
//! ## Format history
//!
//! * **v1** (`HPLVMSNP`) — bare store, no header. Still decodes, with
//!   `meta = None`.
//! * **v2** (`HPLVMSN2`) — adds the [`SnapshotMeta`] header: model name,
//!   `K`, α, β, vocabulary size, and the ring geometry
//!   (`slot`/`n_servers`/`vnodes`). Still decodes, with
//!   `meta.tables = None`.
//! * **v3** (`HPLVMSN3`, current) — appends, after the v2 fields: a
//!   `run_id` nonce identifying the producing training run (slot files
//!   from different runs must never merge, even when every configured
//!   hyperparameter matches), then an *optional table-statistics
//!   section*: one `has_tables` byte, followed (when set) by the
//!   [`TableHyper`] triple `(discount, concentration, root)`.
//!   The per-word table **counts** themselves already travel in the store
//!   body as matrix 1 (`s_tw` for PDP; the root `t_k` row for HDP — see
//!   [`crate::coordinator::model::MATRIX_TABLES`]); v3 adds the
//!   hyperparameters that give those counts meaning, which is what the
//!   PDP/HDP serving families need to rebuild the frozen predictive
//!   distributions. LDA snapshots write `has_tables = 0` and are
//!   byte-identical to v2 apart from the magic and that one byte.
//!
//! Encoders always write the current format; decoders accept all three.
//!
//! Client snapshots have their own two-version history: v1 (shares the
//! `HPLVMSNP` magic) carries shard/iteration/`z`/`r`; v2 (`HPLVMCL2`,
//! current) appends the pulled replica rows in [`RowData`] wire form so
//! a resumed worker starts warm. v1 files still decode (empty replicas).
//!
//! A *session checkpoint* directory additionally carries a
//! [`SessionMeta`] file ([`SESSION_META_NAME`]) next to the slot and
//! client snapshots: run id, completed iteration, RNG epoch, and the
//! config JSON — everything `TrainSession::resume` needs to continue the
//! run in a fresh process under the same `run_id`.

use crate::sampler::counts::{HybridRow, RowData};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

/// A server's store: `(matrix, word) → row`. Rows are [`HybridRow`]s —
/// resident memory scales with each word's occupancy, not `K` — but the
/// on-disk store body is unchanged from the dense era (full-width
/// little-endian cells), so every format version stays bit-compatible.
pub type Store = HashMap<(u8, u32), HybridRow>;

const MAGIC: &[u8; 8] = b"HPLVMSNP";
const MAGIC_V2: &[u8; 8] = b"HPLVMSN2";
const MAGIC_V3: &[u8; 8] = b"HPLVMSN3";

/// Table-side hyperparameters (v3 section) — present for model families
/// whose sufficient statistics include table counts (PDP/HDP).
///
/// The three slots are family-overloaded (a DP is a PDP with discount 0):
///
/// | field           | PDP                  | HDP                       |
/// |-----------------|----------------------|---------------------------|
/// | `discount`      | discount `a`         | `0.0`                     |
/// | `concentration` | concentration `b`    | document-level `b₁`       |
/// | `root`          | word smoothing `γ`   | root concentration `b₀`   |
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TableHyper {
    /// Pitman-Yor discount `a` (0 for the HDP's plain DP).
    pub discount: f64,
    /// Strength of the process the tables belong to (PDP `b`, HDP `b₁`).
    pub concentration: f64,
    /// Root-measure parameter (PDP `γ`, HDP `b₀`).
    pub root: f64,
}

/// Hyperparameters + ring assignment a server store was produced under.
///
/// Written with every v2 store snapshot so a snapshot directory is
/// self-describing: the inference server rebuilds its proposal
/// distributions from `(k, alpha, beta)` and can sanity-check that the
/// slot files it merged really partition the key space (`n_servers`,
/// `vnodes`, `slot`).
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotMeta {
    /// Model display name (e.g. `"AliasLDA"`).
    pub model: String,
    /// Topic count / row width `K`.
    pub k: u32,
    /// Document-topic prior α.
    pub alpha: f64,
    /// Topic-word prior β.
    pub beta: f64,
    /// Vocabulary size the corpus was generated over.
    pub vocab_size: u32,
    /// Ring slot this store belongs to.
    pub slot: u32,
    /// Total logical server slots in the ring.
    pub n_servers: u32,
    /// Virtual ring points per slot.
    pub vnodes: u32,
    /// Training iterations the producing run was *configured* for —
    /// provenance only. The barrier-free design means servers never
    /// observe client progress, so this is not a completed-iteration
    /// count (a mid-run snapshot carries the same value).
    pub iterations: u64,
    /// Per-run nonce (v3): every slot snapshot of one training run
    /// carries the same value, and two runs — even with identical
    /// configuration — carry different ones, so the serving loader can
    /// refuse to merge a directory that mixes runs. 0 for v1/v2 files.
    pub run_id: u64,
    /// v3 table-statistics section: the hyperparameters of the table
    /// counts stored under matrix 1. `None` for LDA snapshots and for
    /// v1/v2 files.
    pub tables: Option<TableHyper>,
}

impl Default for SnapshotMeta {
    fn default() -> Self {
        SnapshotMeta {
            model: String::new(),
            k: 0,
            alpha: 0.0,
            beta: 0.0,
            vocab_size: 0,
            slot: 0,
            n_servers: 1,
            vnodes: 1,
            iterations: 0,
            run_id: 0,
            tables: None,
        }
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}
impl<'a> Reader<'a> {
    fn u32(&mut self) -> Option<u32> {
        let v = u32::from_le_bytes(self.b.get(self.pos..self.pos + 4)?.try_into().ok()?);
        self.pos += 4;
        Some(v)
    }
    fn u64(&mut self) -> Option<u64> {
        let v = u64::from_le_bytes(self.b.get(self.pos..self.pos + 8)?.try_into().ok()?);
        self.pos += 8;
        Some(v)
    }
    fn u8(&mut self) -> Option<u8> {
        let v = *self.b.get(self.pos)?;
        self.pos += 1;
        Some(v)
    }
    fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> Option<String> {
        let len = self.u32()? as usize;
        let bytes = self.b.get(self.pos..self.pos + len)?;
        self.pos += len;
        String::from_utf8(bytes.to_vec()).ok()
    }
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn encode_store_body(buf: &mut Vec<u8>, store: &Store) {
    put_u32(buf, store.len() as u32);
    // Deterministic order for reproducible files.
    let mut keys: Vec<&(u8, u32)> = store.keys().collect();
    keys.sort();
    let mut scratch: Vec<i32> = Vec::new();
    for key in keys {
        let row = &store[key];
        buf.push(key.0);
        put_u32(buf, key.1);
        put_u32(buf, row.k() as u32);
        // Materialize through a reusable scratch row: the body stays the
        // dense-era byte layout regardless of the in-memory form.
        scratch.clear();
        scratch.resize(row.k(), 0);
        row.for_each(|t, v| scratch[t as usize] = v);
        for &v in &scratch {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

fn decode_store_body(r: &mut Reader<'_>) -> Option<Store> {
    let n = r.u32()?;
    let mut store = Store::with_capacity(n as usize);
    for _ in 0..n {
        let matrix = r.u8()?;
        let word = r.u32()?;
        let len = r.u32()? as usize;
        let mut row = Vec::with_capacity(len);
        for _ in 0..len {
            let v = r.u32()? as i32;
            row.push(v);
        }
        // Construct, don't add-diff: cell values (incl. i32::MIN) must
        // land verbatim.
        store.insert((matrix, word), HybridRow::from_dense(&row));
    }
    Some(store)
}

/// Serialize a server store without metadata (legacy v1 format — kept for
/// bit-stable failover tests; new snapshots use [`encode_store_meta`]).
pub fn encode_store(store: &Store) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + store.len() * 32);
    buf.extend_from_slice(MAGIC);
    encode_store_body(&mut buf, store);
    buf
}

fn put_meta_v2_fields(buf: &mut Vec<u8>, meta: &SnapshotMeta) {
    put_str(buf, &meta.model);
    put_u32(buf, meta.k);
    put_f64(buf, meta.alpha);
    put_f64(buf, meta.beta);
    put_u32(buf, meta.vocab_size);
    put_u32(buf, meta.slot);
    put_u32(buf, meta.n_servers);
    put_u32(buf, meta.vnodes);
    put_u64(buf, meta.iterations);
}

/// Serialize a server store with its [`SnapshotMeta`] header (current
/// format, v3).
pub fn encode_store_meta(store: &Store, meta: &SnapshotMeta) -> Vec<u8> {
    let mut buf = Vec::with_capacity(160 + store.len() * 32);
    buf.extend_from_slice(MAGIC_V3);
    put_meta_v2_fields(&mut buf, meta);
    put_u64(&mut buf, meta.run_id);
    match &meta.tables {
        None => buf.push(0),
        Some(t) => {
            buf.push(1);
            put_f64(&mut buf, t.discount);
            put_f64(&mut buf, t.concentration);
            put_f64(&mut buf, t.root);
        }
    }
    encode_store_body(&mut buf, store);
    buf
}

/// Serialize in the legacy v2 layout (no table section). Kept so the
/// backward-compatibility tests can produce genuine v2 bytes; production
/// writers use [`encode_store_meta`]. `meta.tables` is ignored — v2 had
/// nowhere to put it.
pub fn encode_store_meta_v2(store: &Store, meta: &SnapshotMeta) -> Vec<u8> {
    let mut buf = Vec::with_capacity(128 + store.len() * 32);
    buf.extend_from_slice(MAGIC_V2);
    put_meta_v2_fields(&mut buf, meta);
    encode_store_body(&mut buf, store);
    buf
}

/// Parse the magic + metadata header, returning the reader positioned at
/// the store body. Needs only the header bytes — the body may be absent.
fn decode_header(bytes: &[u8]) -> Option<(Option<SnapshotMeta>, Reader<'_>)> {
    if bytes.len() < 12 {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    if &bytes[..8] == MAGIC {
        return Some((None, r));
    }
    let v3 = &bytes[..8] == MAGIC_V3;
    if !v3 && &bytes[..8] != MAGIC_V2 {
        return None;
    }
    let mut meta = SnapshotMeta {
        model: r.str()?,
        k: r.u32()?,
        alpha: r.f64()?,
        beta: r.f64()?,
        vocab_size: r.u32()?,
        slot: r.u32()?,
        n_servers: r.u32()?,
        vnodes: r.u32()?,
        iterations: r.u64()?,
        run_id: 0,
        tables: None,
    };
    if v3 {
        meta.run_id = r.u64()?;
        meta.tables = match r.u8()? {
            0 => None,
            1 => Some(TableHyper {
                discount: r.f64()?,
                concentration: r.f64()?,
                root: r.f64()?,
            }),
            _ => return None,
        };
    }
    Some((Some(meta), r))
}

/// Deserialize a server store plus its metadata (`None` for v1 files;
/// `meta.tables = None` for v2 files).
pub fn decode_store_meta(bytes: &[u8]) -> Option<(Option<SnapshotMeta>, Store)> {
    let (meta, mut r) = decode_header(bytes)?;
    Some((meta, decode_store_body(&mut r)?))
}

/// Decode only the metadata header from a byte *prefix* of a snapshot —
/// the store body may be truncated or absent. `Some(None)` = valid v1
/// prefix (no header); `None` = not a snapshot prefix.
pub fn decode_meta_prefix(bytes: &[u8]) -> Option<Option<SnapshotMeta>> {
    decode_header(bytes).map(|(meta, _)| meta)
}

/// Read just the [`SnapshotMeta`] of a slot file, without loading the
/// store (the header fits comfortably in the first 4 KiB). `None` for
/// missing/corrupt files and headerless v1 files. Cheap enough to poll:
/// the `serve --watch` fingerprint uses the `run_id` this returns to
/// detect same-size same-mtime rewrites.
pub fn read_slot_meta(path: &Path) -> Option<SnapshotMeta> {
    let mut f = std::fs::File::open(path).ok()?;
    let mut buf = [0u8; 4096];
    let mut n = 0;
    loop {
        match f.read(&mut buf[n..]) {
            Ok(0) => break,
            Ok(k) => {
                n += k;
                if n == buf.len() {
                    break;
                }
            }
            Err(_) => return None,
        }
    }
    decode_meta_prefix(&buf[..n])?
}

/// Deserialize a server store (either format), dropping any metadata.
pub fn decode_store(bytes: &[u8]) -> Option<Store> {
    decode_store_meta(bytes).map(|(_, store)| store)
}

/// Canonical server-slot snapshot filename for `slot` — the single
/// source of truth shared by the writer ([`crate::ps::server`]), the
/// loader ([`crate::serve::ServingModel::load_dir`]), and the
/// `serve --watch` poller.
pub fn slot_snapshot_name(slot: usize) -> String {
    format!("server_slot{slot}.snap")
}

/// Does `name` name a server-slot snapshot file?
pub fn is_slot_snapshot_name(name: &str) -> bool {
    name.starts_with("server_slot") && name.ends_with(".snap")
}

/// Write bytes atomically (temp file + rename).
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

/// Read a snapshot file if present and well-formed.
pub fn read_snapshot(path: &Path) -> Option<Vec<u8>> {
    let mut f = std::fs::File::open(path).ok()?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).ok()?;
    Some(buf)
}

const MAGIC_SESSION: &[u8; 8] = b"HPLVMSES";

/// Canonical session-meta filename inside a checkpoint directory.
pub const SESSION_META_NAME: &str = "session.meta";

/// The session-level state of a training-cluster checkpoint: everything
/// [`TrainSession::resume`](crate::coordinator::TrainSession::resume)
/// needs beyond the server slot snapshots and the per-shard client
/// snapshots that sit next to it in the checkpoint directory.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SessionMeta {
    /// The run nonce every slot snapshot of this run carries. A resumed
    /// session keeps training under the same id, so its later snapshots
    /// still pass the serving layer's same-run merge check.
    pub run_id: u64,
    /// Completed iterations at checkpoint time (the resumed session's
    /// next segment starts here).
    pub iteration: u64,
    /// Segment counter — salts the per-segment worker RNG streams so a
    /// resumed run does not replay the randomness of segment 1.
    pub epoch: u64,
    /// The run's global seed.
    pub seed: u64,
    /// The training configuration as [`crate::config::TrainConfig::to_json`]
    /// text (the preset subset — enough to rebuild the topology and, for
    /// synthetic corpora, regenerate the identical corpus).
    pub config_json: String,
    /// Docword file backing the corpus, when trained from a
    /// [`FileSource`](crate::corpus::FileSource); `None` = synthetic.
    pub corpus_file: Option<String>,
    /// Companion vocabulary file of the [`FileSource`], when one widened
    /// the effective vocabulary — resume must rebuild the same `V`.
    ///
    /// [`FileSource`]: crate::corpus::FileSource
    pub vocab_file: Option<String>,
}

/// Serialize a [`SessionMeta`].
pub fn encode_session(m: &SessionMeta) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + m.config_json.len());
    buf.extend_from_slice(MAGIC_SESSION);
    put_u64(&mut buf, m.run_id);
    put_u64(&mut buf, m.iteration);
    put_u64(&mut buf, m.epoch);
    put_u64(&mut buf, m.seed);
    put_str(&mut buf, &m.config_json);
    for path in [&m.corpus_file, &m.vocab_file] {
        match path {
            None => buf.push(0),
            Some(p) => {
                buf.push(1);
                put_str(&mut buf, p);
            }
        }
    }
    buf
}

/// Deserialize a [`SessionMeta`].
pub fn decode_session(bytes: &[u8]) -> Option<SessionMeta> {
    if bytes.len() < 8 || &bytes[..8] != MAGIC_SESSION {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    let run_id = r.u64()?;
    let iteration = r.u64()?;
    let epoch = r.u64()?;
    let seed = r.u64()?;
    let config_json = r.str()?;
    let mut opt_str = || -> Option<Option<String>> {
        match r.u8()? {
            0 => Some(None),
            1 => Some(Some(r.str()?)),
            _ => None,
        }
    };
    let corpus_file = opt_str()?;
    let vocab_file = opt_str()?;
    Some(SessionMeta {
        run_id,
        iteration,
        epoch,
        seed,
        config_json,
        corpus_file,
        vocab_file,
    })
}

const MAGIC_CLIENT_V2: &[u8; 8] = b"HPLVMCL2";

/// A client's resumable state: its shard, completed iterations, all
/// topic assignments (`z`, plus the PDP/HDP table indicators), and —
/// since client-format v2 — the pulled replica rows, so a resumed worker
/// samples against the cluster-wide counts immediately instead of
/// shard-local ones until its first post-resume pull.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientSnapshot {
    /// Shard this client was working.
    pub shard: usize,
    /// Completed iterations.
    pub iteration: u64,
    /// Flattened topic assignments, per document.
    pub z: Vec<Vec<u32>>,
    /// Flattened table indicators, per document (empty for LDA).
    pub r: Vec<Vec<bool>>,
    /// Pulled replica rows at snapshot time, per matrix id
    /// (`(matrix, [(word, row)])`), in wire form. Empty for legacy (v1)
    /// files; restore is then skipped and the first pull warms the
    /// replica as before.
    pub replicas: Vec<(u8, Vec<(u32, RowData)>)>,
}

fn put_rowdata(buf: &mut Vec<u8>, data: &RowData) {
    match data {
        RowData::Dense(r) => {
            buf.push(0);
            put_u32(buf, r.len() as u32);
            for &v in r.iter() {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        RowData::Sparse(es) => {
            buf.push(1);
            put_u32(buf, es.len() as u32);
            for &(t, v) in es {
                put_u32(buf, t);
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
}

fn read_rowdata(r: &mut Reader<'_>) -> Option<RowData> {
    match r.u8()? {
        0 => {
            let len = r.u32()? as usize;
            let mut row = Vec::with_capacity(len);
            for _ in 0..len {
                row.push(r.u32()? as i32);
            }
            Some(RowData::Dense(row.into_boxed_slice()))
        }
        1 => {
            let len = r.u32()? as usize;
            let mut es = Vec::with_capacity(len);
            for _ in 0..len {
                let t = r.u32()?;
                let v = r.u32()? as i32;
                es.push((t, v));
            }
            Some(RowData::Sparse(es))
        }
        _ => None,
    }
}

/// Serialize a client snapshot (current format, v2: appends the replica
/// section after the v1 fields).
pub fn encode_client(s: &ClientSnapshot) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC_CLIENT_V2);
    put_u64(&mut buf, s.shard as u64);
    put_u64(&mut buf, s.iteration);
    put_u32(&mut buf, s.z.len() as u32);
    let empty: Vec<bool> = Vec::new();
    for (i, zd) in s.z.iter().enumerate() {
        let rd = s.r.get(i).unwrap_or(&empty);
        put_u32(&mut buf, zd.len() as u32);
        for &z in zd {
            put_u32(&mut buf, z);
        }
        put_u32(&mut buf, rd.len() as u32);
        let mut bits = vec![0u8; rd.len().div_ceil(8)];
        for (i, &b) in rd.iter().enumerate() {
            if b {
                bits[i / 8] |= 1 << (i % 8);
            }
        }
        buf.extend_from_slice(&bits);
    }
    put_u32(&mut buf, s.replicas.len() as u32);
    for (matrix, rows) in &s.replicas {
        buf.push(*matrix);
        put_u32(&mut buf, rows.len() as u32);
        for (w, data) in rows {
            put_u32(&mut buf, *w);
            put_rowdata(&mut buf, data);
        }
    }
    buf
}

/// Deserialize a client snapshot — current (v2) or legacy (v1, shares
/// the store-v1 magic; decodes with `replicas` empty).
pub fn decode_client(bytes: &[u8]) -> Option<ClientSnapshot> {
    if bytes.len() < 8 {
        return None;
    }
    let v2 = &bytes[..8] == MAGIC_CLIENT_V2;
    if !v2 && &bytes[..8] != MAGIC {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    let shard = r.u64()? as usize;
    let iteration = r.u64()?;
    let ndocs = r.u32()? as usize;
    let mut z = Vec::with_capacity(ndocs);
    let mut rr = Vec::with_capacity(ndocs);
    for _ in 0..ndocs {
        let len = r.u32()? as usize;
        let mut zd = Vec::with_capacity(len);
        for _ in 0..len {
            zd.push(r.u32()?);
        }
        let rlen = r.u32()? as usize;
        let nbytes = rlen.div_ceil(8);
        let mut rd = Vec::with_capacity(rlen);
        let start = r.pos;
        if start + nbytes > r.b.len() {
            return None;
        }
        for i in 0..rlen {
            rd.push(r.b[start + i / 8] & (1 << (i % 8)) != 0);
        }
        r.pos += nbytes;
        z.push(zd);
        rr.push(rd);
    }
    let mut replicas = Vec::new();
    if v2 {
        let nmat = r.u32()? as usize;
        for _ in 0..nmat {
            let matrix = r.u8()?;
            let nrows = r.u32()? as usize;
            let mut rows = Vec::with_capacity(nrows);
            for _ in 0..nrows {
                let w = r.u32()?;
                rows.push((w, read_rowdata(&mut r)?));
            }
            replicas.push((matrix, rows));
        }
    }
    Some(ClientSnapshot {
        shard,
        iteration,
        z,
        r: rr,
        replicas,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip() {
        let mut store = Store::new();
        store.insert((0, 5), vec![1, -2, 3].into());
        store.insert((1, 0), vec![0; 8].into());
        store.insert((0, 1000), vec![i32::MAX, i32::MIN].into());
        let bytes = encode_store(&store);
        let back = decode_store(&bytes).unwrap();
        assert_eq!(store, back);
    }

    #[test]
    fn store_rejects_garbage() {
        assert!(decode_store(b"nonsense").is_none());
        assert!(decode_store(&[]).is_none());
        let mut bytes = encode_store(&Store::new());
        bytes[0] ^= 0xFF;
        assert!(decode_store(&bytes).is_none());
    }

    fn sample_meta() -> SnapshotMeta {
        SnapshotMeta {
            model: "AliasLDA".to_string(),
            k: 20,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 2_000,
            slot: 1,
            n_servers: 2,
            vnodes: 64,
            iterations: 17,
            run_id: 0xDEAD_BEEF,
            tables: None,
        }
    }

    fn sample_meta_tables() -> SnapshotMeta {
        let mut meta = sample_meta();
        meta.model = "AliasPDP".to_string();
        meta.tables = Some(TableHyper {
            discount: 0.1,
            concentration: 10.0,
            root: 0.5,
        });
        meta
    }

    /// Satellite: save → load reproduces counts, hyperparameters, and the
    /// ring assignment bit-for-bit — with and without the v3 table
    /// section.
    #[test]
    fn store_meta_roundtrip_bit_for_bit() {
        let mut store = Store::new();
        store.insert((0, 3), vec![7, 0, -1, 4].into());
        store.insert((1, 0), vec![2; 4].into());
        for meta in [sample_meta(), sample_meta_tables()] {
            let bytes = encode_store_meta(&store, &meta);
            let (meta2, store2) = decode_store_meta(&bytes).unwrap();
            let meta2 = meta2.expect("v3 snapshot must carry metadata");
            assert_eq!(meta2, meta);
            assert_eq!(store2, store);
            // Hyperparameters survive exactly (f64 bit patterns, not text).
            assert_eq!(meta2.alpha.to_bits(), 0.1f64.to_bits());
            assert_eq!(meta2.beta.to_bits(), 0.01f64.to_bits());
            // Encoding is deterministic: same input, same bytes.
            assert_eq!(bytes, encode_store_meta(&store, &meta));
        }
    }

    #[test]
    fn v1_files_decode_with_no_meta() {
        let mut store = Store::new();
        store.insert((0, 9), vec![1, 2].into());
        let bytes = encode_store(&store);
        let (meta, back) = decode_store_meta(&bytes).unwrap();
        assert!(meta.is_none());
        assert_eq!(back, store);
        // And the plain decoder reads every format.
        let v3 = encode_store_meta(&store, &sample_meta_tables());
        assert_eq!(decode_store(&v3).unwrap(), store);
    }

    #[test]
    fn v2_files_decode_with_no_table_section() {
        let mut store = Store::new();
        store.insert((0, 9), vec![1, 2].into());
        store.insert((1, 9), vec![0, 1].into());
        // Encode with the legacy writer: genuine v2 bytes.
        let bytes = encode_store_meta_v2(&store, &sample_meta_tables());
        let (meta, back) = decode_store_meta(&bytes).unwrap();
        let meta = meta.expect("v2 carries a header");
        assert_eq!(meta.model, "AliasPDP");
        assert_eq!(meta.k, 20);
        assert!(meta.tables.is_none(), "v2 has no table section");
        assert_eq!(meta.run_id, 0, "v2 has no run id");
        assert_eq!(back, store);
    }

    #[test]
    fn truncated_v2_and_v3_rejected() {
        for meta in [sample_meta(), sample_meta_tables()] {
            let bytes = encode_store_meta(&Store::new(), &meta);
            for cut in [9, 15, bytes.len() - 1] {
                assert!(
                    decode_store_meta(&bytes[..cut]).is_none(),
                    "truncation at {cut} accepted"
                );
            }
        }
        let v2 = encode_store_meta_v2(&Store::new(), &sample_meta());
        assert!(decode_store_meta(&v2[..v2.len() - 1]).is_none());
    }

    #[test]
    fn meta_prefix_and_slot_meta_read_header_only() {
        let mut store = Store::new();
        for w in 0..50u32 {
            store.insert((0, w), vec![1; 32].into());
        }
        let meta = sample_meta_tables();
        let bytes = encode_store_meta(&store, &meta);
        // A header-sized prefix is enough — the body can be cut off.
        let prefix = &bytes[..256.min(bytes.len())];
        let got = decode_meta_prefix(prefix).unwrap().unwrap();
        assert_eq!(got, meta);
        assert_eq!(got.run_id, 0xDEAD_BEEF);
        // v1 prefixes carry no header; garbage is rejected.
        assert_eq!(decode_meta_prefix(&encode_store(&store)[..16]), Some(None));
        assert!(decode_meta_prefix(b"nonsense----").is_none());

        // File-backed variant (the --watch poller's probe).
        let dir = std::env::temp_dir().join(format!(
            "hplvm_snap_meta_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = dir.join("server_slot0.snap");
        write_atomic(&path, &bytes).unwrap();
        assert_eq!(read_slot_meta(&path).unwrap(), meta);
        assert!(read_slot_meta(&dir.join("missing.snap")).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v3_garbage_table_flag_rejected() {
        let meta = sample_meta();
        let mut bytes = encode_store_meta(&Store::new(), &meta);
        // The has_tables byte sits after the fixed v2 fields + run_id.
        let flag_pos = 8 + 4 + meta.model.len() + 4 + 8 + 8 + 4 + 4 + 4 + 4 + 8 + 8;
        assert_eq!(bytes[flag_pos], 0);
        bytes[flag_pos] = 7;
        assert!(decode_store_meta(&bytes).is_none());
    }

    #[test]
    fn client_roundtrip() {
        let snap = ClientSnapshot {
            shard: 3,
            iteration: 17,
            z: vec![vec![1, 2, 3], vec![], vec![9; 20]],
            r: vec![vec![true, false, true], vec![], vec![false; 20]],
            replicas: vec![
                (
                    0,
                    vec![
                        (4, RowData::Sparse(vec![(0, 2), (7, -1)])),
                        (9, RowData::Dense(vec![1, 0, 3, 0].into_boxed_slice())),
                    ],
                ),
                (1, vec![(4, RowData::Sparse(vec![(2, 5)]))]),
            ],
        };
        let bytes = encode_client(&snap);
        let back = decode_client(&bytes).unwrap();
        assert_eq!(snap, back);
        // Truncations inside the replica section are rejected.
        for cut in [bytes.len() - 1, bytes.len() - 5] {
            assert!(decode_client(&bytes[..cut]).is_none(), "cut {cut}");
        }
    }

    /// Legacy (v1) client snapshots — the old store-v1 magic, no replica
    /// section — still decode, with `replicas` empty.
    #[test]
    fn client_v1_decodes_with_empty_replicas() {
        let snap = ClientSnapshot {
            shard: 1,
            iteration: 5,
            z: vec![vec![2, 0]],
            r: vec![vec![true, true]],
            replicas: vec![(0, vec![(3, RowData::Sparse(vec![(1, 1)]))])],
        };
        // Hand-build the v1 bytes: swap the magic, cut the replica tail.
        let v2 = encode_client(&snap);
        let mut v1 = v2.clone();
        v1[..8].copy_from_slice(MAGIC);
        // The replica section is the suffix after z/r; find it by
        // encoding the same snapshot with no replicas.
        let bare = encode_client(&ClientSnapshot {
            replicas: Vec::new(),
            ..snap.clone()
        });
        v1.truncate(bare.len() - 4); // minus the empty replica count
        let back = decode_client(&v1).unwrap();
        assert_eq!(back.shard, snap.shard);
        assert_eq!(back.z, snap.z);
        assert_eq!(back.r, snap.r);
        assert!(back.replicas.is_empty());
    }

    #[test]
    fn session_meta_roundtrip_and_rejects_garbage() {
        for (corpus_file, vocab_file) in [
            (None, None),
            (Some("data/docword.txt".to_string()), None),
            (
                Some("data/docword.txt".to_string()),
                Some("data/vocab.txt".to_string()),
            ),
        ] {
            let m = SessionMeta {
                run_id: 0xFEED_F00D,
                iteration: 40,
                epoch: 3,
                seed: 42,
                config_json: r#"{"model":"AliasLDA","topics":20}"#.to_string(),
                corpus_file,
                vocab_file,
            };
            let bytes = encode_session(&m);
            assert_eq!(decode_session(&bytes).unwrap(), m);
            // Every truncation is rejected, never mis-decoded.
            for cut in [0, 7, 9, 33, bytes.len() - 1] {
                assert!(decode_session(&bytes[..cut]).is_none(), "cut {cut}");
            }
        }
        assert!(decode_session(b"nonsense----------------").is_none());
        // A store snapshot is not a session meta.
        assert!(decode_session(&encode_store(&Store::new())).is_none());
    }

    #[test]
    fn atomic_write_and_read() {
        let dir = std::env::temp_dir().join(format!("hplvm_snap_test_{}", std::process::id()));
        let path = dir.join("s.snap");
        let mut store = Store::new();
        store.insert((0, 1), vec![42].into());
        write_atomic(&path, &encode_store(&store)).unwrap();
        let bytes = read_snapshot(&path).unwrap();
        assert_eq!(decode_store(&bytes).unwrap(), store);
        std::fs::remove_dir_all(&dir).ok();
    }
}
