//! Barrier-free snapshots (§5.4).
//!
//! "Clients and servers independently take a snapshot of their memory to
//! disk every N minutes without global barrier." Snapshots are plain
//! binary files written atomically (temp + rename); a replacement node
//! loads the most recent one and continues — rolling only *itself* back,
//! which is the paper's deliberately relaxed failover semantics.
//!
//! Server snapshots carry a [`SnapshotMeta`] header (format v2) recording
//! the hyperparameters (model, K, α, β) and the ring assignment the store
//! was sharded under — everything the serving layer ([`crate::serve`])
//! needs to rebuild proposal distributions without the training config.
//! v1 files (no header) still decode, with `meta = None`.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

/// A server's store: `(matrix, word) → row`.
pub type Store = HashMap<(u8, u32), Vec<i32>>;

const MAGIC: &[u8; 8] = b"HPLVMSNP";
const MAGIC_V2: &[u8; 8] = b"HPLVMSN2";

/// Hyperparameters + ring assignment a server store was produced under.
///
/// Written with every v2 store snapshot so a snapshot directory is
/// self-describing: the inference server rebuilds its proposal
/// distributions from `(k, alpha, beta)` and can sanity-check that the
/// slot files it merged really partition the key space (`n_servers`,
/// `vnodes`, `slot`).
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotMeta {
    /// Model display name (e.g. `"AliasLDA"`).
    pub model: String,
    /// Topic count / row width `K`.
    pub k: u32,
    /// Document-topic prior α.
    pub alpha: f64,
    /// Topic-word prior β.
    pub beta: f64,
    /// Vocabulary size the corpus was generated over.
    pub vocab_size: u32,
    /// Ring slot this store belongs to.
    pub slot: u32,
    /// Total logical server slots in the ring.
    pub n_servers: u32,
    /// Virtual ring points per slot.
    pub vnodes: u32,
    /// Training iterations the producing run was *configured* for —
    /// provenance only. The barrier-free design means servers never
    /// observe client progress, so this is not a completed-iteration
    /// count (a mid-run snapshot carries the same value).
    pub iterations: u64,
}

impl Default for SnapshotMeta {
    fn default() -> Self {
        SnapshotMeta {
            model: String::new(),
            k: 0,
            alpha: 0.0,
            beta: 0.0,
            vocab_size: 0,
            slot: 0,
            n_servers: 1,
            vnodes: 1,
            iterations: 0,
        }
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}
impl<'a> Reader<'a> {
    fn u32(&mut self) -> Option<u32> {
        let v = u32::from_le_bytes(self.b.get(self.pos..self.pos + 4)?.try_into().ok()?);
        self.pos += 4;
        Some(v)
    }
    fn u64(&mut self) -> Option<u64> {
        let v = u64::from_le_bytes(self.b.get(self.pos..self.pos + 8)?.try_into().ok()?);
        self.pos += 8;
        Some(v)
    }
    fn u8(&mut self) -> Option<u8> {
        let v = *self.b.get(self.pos)?;
        self.pos += 1;
        Some(v)
    }
    fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> Option<String> {
        let len = self.u32()? as usize;
        let bytes = self.b.get(self.pos..self.pos + len)?;
        self.pos += len;
        String::from_utf8(bytes.to_vec()).ok()
    }
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn encode_store_body(buf: &mut Vec<u8>, store: &Store) {
    put_u32(buf, store.len() as u32);
    // Deterministic order for reproducible files.
    let mut keys: Vec<&(u8, u32)> = store.keys().collect();
    keys.sort();
    for key in keys {
        let row = &store[key];
        buf.push(key.0);
        put_u32(buf, key.1);
        put_u32(buf, row.len() as u32);
        for &v in row {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

fn decode_store_body(r: &mut Reader<'_>) -> Option<Store> {
    let n = r.u32()?;
    let mut store = Store::with_capacity(n as usize);
    for _ in 0..n {
        let matrix = r.u8()?;
        let word = r.u32()?;
        let len = r.u32()? as usize;
        let mut row = Vec::with_capacity(len);
        for _ in 0..len {
            let v = r.u32()? as i32;
            row.push(v);
        }
        store.insert((matrix, word), row);
    }
    Some(store)
}

/// Serialize a server store without metadata (legacy v1 format — kept for
/// bit-stable failover tests; new snapshots use [`encode_store_meta`]).
pub fn encode_store(store: &Store) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + store.len() * 32);
    buf.extend_from_slice(MAGIC);
    encode_store_body(&mut buf, store);
    buf
}

/// Serialize a server store with its [`SnapshotMeta`] header (format v2).
pub fn encode_store_meta(store: &Store, meta: &SnapshotMeta) -> Vec<u8> {
    let mut buf = Vec::with_capacity(128 + store.len() * 32);
    buf.extend_from_slice(MAGIC_V2);
    put_str(&mut buf, &meta.model);
    put_u32(&mut buf, meta.k);
    put_f64(&mut buf, meta.alpha);
    put_f64(&mut buf, meta.beta);
    put_u32(&mut buf, meta.vocab_size);
    put_u32(&mut buf, meta.slot);
    put_u32(&mut buf, meta.n_servers);
    put_u32(&mut buf, meta.vnodes);
    put_u64(&mut buf, meta.iterations);
    encode_store_body(&mut buf, store);
    buf
}

/// Deserialize a server store plus its metadata (`None` for v1 files).
pub fn decode_store_meta(bytes: &[u8]) -> Option<(Option<SnapshotMeta>, Store)> {
    if bytes.len() < 12 {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    if &bytes[..8] == MAGIC {
        return Some((None, decode_store_body(&mut r)?));
    }
    if &bytes[..8] != MAGIC_V2 {
        return None;
    }
    let meta = SnapshotMeta {
        model: r.str()?,
        k: r.u32()?,
        alpha: r.f64()?,
        beta: r.f64()?,
        vocab_size: r.u32()?,
        slot: r.u32()?,
        n_servers: r.u32()?,
        vnodes: r.u32()?,
        iterations: r.u64()?,
    };
    Some((Some(meta), decode_store_body(&mut r)?))
}

/// Deserialize a server store (either format), dropping any metadata.
pub fn decode_store(bytes: &[u8]) -> Option<Store> {
    decode_store_meta(bytes).map(|(_, store)| store)
}

/// Write bytes atomically (temp file + rename).
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

/// Read a snapshot file if present and well-formed.
pub fn read_snapshot(path: &Path) -> Option<Vec<u8>> {
    let mut f = std::fs::File::open(path).ok()?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).ok()?;
    Some(buf)
}

/// A client's resumable state: its shard, completed iterations, and all
/// topic assignments (`z`, plus the PDP/HDP table indicators).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientSnapshot {
    /// Shard this client was working.
    pub shard: usize,
    /// Completed iterations.
    pub iteration: u64,
    /// Flattened topic assignments, per document.
    pub z: Vec<Vec<u32>>,
    /// Flattened table indicators, per document (empty for LDA).
    pub r: Vec<Vec<bool>>,
}

/// Serialize a client snapshot.
pub fn encode_client(s: &ClientSnapshot) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    put_u64(&mut buf, s.shard as u64);
    put_u64(&mut buf, s.iteration);
    put_u32(&mut buf, s.z.len() as u32);
    let empty: Vec<bool> = Vec::new();
    for (i, zd) in s.z.iter().enumerate() {
        let rd = s.r.get(i).unwrap_or(&empty);
        put_u32(&mut buf, zd.len() as u32);
        for &z in zd {
            put_u32(&mut buf, z);
        }
        put_u32(&mut buf, rd.len() as u32);
        let mut bits = vec![0u8; rd.len().div_ceil(8)];
        for (i, &b) in rd.iter().enumerate() {
            if b {
                bits[i / 8] |= 1 << (i % 8);
            }
        }
        buf.extend_from_slice(&bits);
    }
    buf
}

/// Deserialize a client snapshot.
pub fn decode_client(bytes: &[u8]) -> Option<ClientSnapshot> {
    if bytes.len() < 8 || &bytes[..8] != MAGIC {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    let shard = r.u64()? as usize;
    let iteration = r.u64()?;
    let ndocs = r.u32()? as usize;
    let mut z = Vec::with_capacity(ndocs);
    let mut rr = Vec::with_capacity(ndocs);
    for _ in 0..ndocs {
        let len = r.u32()? as usize;
        let mut zd = Vec::with_capacity(len);
        for _ in 0..len {
            zd.push(r.u32()?);
        }
        let rlen = r.u32()? as usize;
        let nbytes = rlen.div_ceil(8);
        let mut rd = Vec::with_capacity(rlen);
        let start = r.pos;
        if start + nbytes > r.b.len() {
            return None;
        }
        for i in 0..rlen {
            rd.push(r.b[start + i / 8] & (1 << (i % 8)) != 0);
        }
        r.pos += nbytes;
        z.push(zd);
        rr.push(rd);
    }
    Some(ClientSnapshot {
        shard,
        iteration,
        z,
        r: rr,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip() {
        let mut store = Store::new();
        store.insert((0, 5), vec![1, -2, 3]);
        store.insert((1, 0), vec![0; 8]);
        store.insert((0, 1000), vec![i32::MAX, i32::MIN]);
        let bytes = encode_store(&store);
        let back = decode_store(&bytes).unwrap();
        assert_eq!(store, back);
    }

    #[test]
    fn store_rejects_garbage() {
        assert!(decode_store(b"nonsense").is_none());
        assert!(decode_store(&[]).is_none());
        let mut bytes = encode_store(&Store::new());
        bytes[0] ^= 0xFF;
        assert!(decode_store(&bytes).is_none());
    }

    fn sample_meta() -> SnapshotMeta {
        SnapshotMeta {
            model: "AliasLDA".to_string(),
            k: 20,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 2_000,
            slot: 1,
            n_servers: 2,
            vnodes: 64,
            iterations: 17,
        }
    }

    /// Satellite: save → load reproduces counts, hyperparameters, and the
    /// ring assignment bit-for-bit (covers the new v2 metadata fields).
    #[test]
    fn store_meta_roundtrip_bit_for_bit() {
        let mut store = Store::new();
        store.insert((0, 3), vec![7, 0, -1, 4]);
        store.insert((1, 0), vec![2; 4]);
        let meta = sample_meta();
        let bytes = encode_store_meta(&store, &meta);
        let (meta2, store2) = decode_store_meta(&bytes).unwrap();
        let meta2 = meta2.expect("v2 snapshot must carry metadata");
        assert_eq!(meta2, meta);
        assert_eq!(store2, store);
        // Hyperparameters survive exactly (f64 bit patterns, not text).
        assert_eq!(meta2.alpha.to_bits(), 0.1f64.to_bits());
        assert_eq!(meta2.beta.to_bits(), 0.01f64.to_bits());
        // Encoding is deterministic: same input, same bytes.
        assert_eq!(bytes, encode_store_meta(&store, &meta));
    }

    #[test]
    fn v1_files_decode_with_no_meta() {
        let mut store = Store::new();
        store.insert((0, 9), vec![1, 2]);
        let bytes = encode_store(&store);
        let (meta, back) = decode_store_meta(&bytes).unwrap();
        assert!(meta.is_none());
        assert_eq!(back, store);
        // And the plain decoder reads both formats.
        let v2 = encode_store_meta(&store, &sample_meta());
        assert_eq!(decode_store(&v2).unwrap(), store);
    }

    #[test]
    fn truncated_v2_rejected() {
        let bytes = encode_store_meta(&Store::new(), &sample_meta());
        for cut in [9, 15, bytes.len() - 1] {
            assert!(
                decode_store_meta(&bytes[..cut]).is_none(),
                "truncation at {cut} accepted"
            );
        }
    }

    #[test]
    fn client_roundtrip() {
        let snap = ClientSnapshot {
            shard: 3,
            iteration: 17,
            z: vec![vec![1, 2, 3], vec![], vec![9; 20]],
            r: vec![vec![true, false, true], vec![], vec![false; 20]],
        };
        let bytes = encode_client(&snap);
        let back = decode_client(&bytes).unwrap();
        assert_eq!(snap, back);
    }

    #[test]
    fn atomic_write_and_read() {
        let dir = std::env::temp_dir().join(format!("hplvm_snap_test_{}", std::process::id()));
        let path = dir.join("s.snap");
        let mut store = Store::new();
        store.insert((0, 1), vec![42]);
        write_atomic(&path, &encode_store(&store)).unwrap();
        let bytes = read_snapshot(&path).unwrap();
        assert_eq!(decode_store(&bytes).unwrap(), store);
        std::fs::remove_dir_all(&dir).ok();
    }
}
