//! Barrier-free snapshots (§5.4).
//!
//! "Clients and servers independently take a snapshot of their memory to
//! disk every N minutes without global barrier." Snapshots are plain
//! binary files written atomically (temp + rename); a replacement node
//! loads the most recent one and continues — rolling only *itself* back,
//! which is the paper's deliberately relaxed failover semantics.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

/// A server's store: `(matrix, word) → row`.
pub type Store = HashMap<(u8, u32), Vec<i32>>;

const MAGIC: &[u8; 8] = b"HPLVMSNP";

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}
impl<'a> Reader<'a> {
    fn u32(&mut self) -> Option<u32> {
        let v = u32::from_le_bytes(self.b.get(self.pos..self.pos + 4)?.try_into().ok()?);
        self.pos += 4;
        Some(v)
    }
    fn u64(&mut self) -> Option<u64> {
        let v = u64::from_le_bytes(self.b.get(self.pos..self.pos + 8)?.try_into().ok()?);
        self.pos += 8;
        Some(v)
    }
    fn u8(&mut self) -> Option<u8> {
        let v = *self.b.get(self.pos)?;
        self.pos += 1;
        Some(v)
    }
}

/// Serialize a server store.
pub fn encode_store(store: &Store) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + store.len() * 32);
    buf.extend_from_slice(MAGIC);
    put_u32(&mut buf, store.len() as u32);
    // Deterministic order for reproducible files.
    let mut keys: Vec<&(u8, u32)> = store.keys().collect();
    keys.sort();
    for key in keys {
        let row = &store[key];
        buf.push(key.0);
        put_u32(&mut buf, key.1);
        put_u32(&mut buf, row.len() as u32);
        for &v in row {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    buf
}

/// Deserialize a server store.
pub fn decode_store(bytes: &[u8]) -> Option<Store> {
    if bytes.len() < 12 || &bytes[..8] != MAGIC {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    let n = r.u32()?;
    let mut store = Store::with_capacity(n as usize);
    for _ in 0..n {
        let matrix = r.u8()?;
        let word = r.u32()?;
        let len = r.u32()? as usize;
        let mut row = Vec::with_capacity(len);
        for _ in 0..len {
            let v = r.u32()? as i32;
            row.push(v);
        }
        store.insert((matrix, word), row);
    }
    Some(store)
}

/// Write bytes atomically (temp file + rename).
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

/// Read a snapshot file if present and well-formed.
pub fn read_snapshot(path: &Path) -> Option<Vec<u8>> {
    let mut f = std::fs::File::open(path).ok()?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).ok()?;
    Some(buf)
}

/// A client's resumable state: its shard, completed iterations, and all
/// topic assignments (`z`, plus the PDP/HDP table indicators).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientSnapshot {
    /// Shard this client was working.
    pub shard: usize,
    /// Completed iterations.
    pub iteration: u64,
    /// Flattened topic assignments, per document.
    pub z: Vec<Vec<u32>>,
    /// Flattened table indicators, per document (empty for LDA).
    pub r: Vec<Vec<bool>>,
}

/// Serialize a client snapshot.
pub fn encode_client(s: &ClientSnapshot) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    put_u64(&mut buf, s.shard as u64);
    put_u64(&mut buf, s.iteration);
    put_u32(&mut buf, s.z.len() as u32);
    let empty: Vec<bool> = Vec::new();
    for (i, zd) in s.z.iter().enumerate() {
        let rd = s.r.get(i).unwrap_or(&empty);
        put_u32(&mut buf, zd.len() as u32);
        for &z in zd {
            put_u32(&mut buf, z);
        }
        put_u32(&mut buf, rd.len() as u32);
        let mut bits = vec![0u8; rd.len().div_ceil(8)];
        for (i, &b) in rd.iter().enumerate() {
            if b {
                bits[i / 8] |= 1 << (i % 8);
            }
        }
        buf.extend_from_slice(&bits);
    }
    buf
}

/// Deserialize a client snapshot.
pub fn decode_client(bytes: &[u8]) -> Option<ClientSnapshot> {
    if bytes.len() < 8 || &bytes[..8] != MAGIC {
        return None;
    }
    let mut r = Reader { b: bytes, pos: 8 };
    let shard = r.u64()? as usize;
    let iteration = r.u64()?;
    let ndocs = r.u32()? as usize;
    let mut z = Vec::with_capacity(ndocs);
    let mut rr = Vec::with_capacity(ndocs);
    for _ in 0..ndocs {
        let len = r.u32()? as usize;
        let mut zd = Vec::with_capacity(len);
        for _ in 0..len {
            zd.push(r.u32()?);
        }
        let rlen = r.u32()? as usize;
        let nbytes = rlen.div_ceil(8);
        let mut rd = Vec::with_capacity(rlen);
        let start = r.pos;
        if start + nbytes > r.b.len() {
            return None;
        }
        for i in 0..rlen {
            rd.push(r.b[start + i / 8] & (1 << (i % 8)) != 0);
        }
        r.pos += nbytes;
        z.push(zd);
        rr.push(rd);
    }
    Some(ClientSnapshot {
        shard,
        iteration,
        z,
        r: rr,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip() {
        let mut store = Store::new();
        store.insert((0, 5), vec![1, -2, 3]);
        store.insert((1, 0), vec![0; 8]);
        store.insert((0, 1000), vec![i32::MAX, i32::MIN]);
        let bytes = encode_store(&store);
        let back = decode_store(&bytes).unwrap();
        assert_eq!(store, back);
    }

    #[test]
    fn store_rejects_garbage() {
        assert!(decode_store(b"nonsense").is_none());
        assert!(decode_store(&[]).is_none());
        let mut bytes = encode_store(&Store::new());
        bytes[0] ^= 0xFF;
        assert!(decode_store(&bytes).is_none());
    }

    #[test]
    fn client_roundtrip() {
        let snap = ClientSnapshot {
            shard: 3,
            iteration: 17,
            z: vec![vec![1, 2, 3], vec![], vec![9; 20]],
            r: vec![vec![true, false, true], vec![], vec![false; 20]],
        };
        let bytes = encode_client(&snap);
        let back = decode_client(&bytes).unwrap();
        assert_eq!(snap, back);
    }

    #[test]
    fn atomic_write_and_read() {
        let dir = std::env::temp_dir().join(format!("hplvm_snap_test_{}", std::process::id()));
        let path = dir.join("s.snap");
        let mut store = Store::new();
        store.insert((0, 1), vec![42]);
        write_atomic(&path, &encode_store(&store)).unwrap();
        let bytes = read_snapshot(&path).unwrap();
        assert_eq!(decode_store(&bytes).unwrap(), store);
        std::fs::remove_dir_all(&dir).ok();
    }
}
