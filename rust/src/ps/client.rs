//! The client-side parameter-server API: asynchronous push/pull with
//! batched rows, communication filters, and the freeze protocol.
//!
//! A client never blocks on synchronization (eventual consistency, §5.3):
//! `push_matrix` drains a replica's delta log through the filter and fires
//! the batches at the owning servers; `request_rows` fires pull requests;
//! `drain_responses` collects whatever has arrived. The worker folds
//! responses into its replicas between documents.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

use super::filter::Filter;
use super::msg::{NodeId, Payload, RowBatch};
use super::network::SimNet;
use super::ring::SharedRing;
use crate::sampler::counts::CountMatrix;
use crate::util::rng::Rng;

/// Client-side handle to the server group.
pub struct PsClient {
    /// This client's node id.
    pub id: NodeId,
    net: SimNet,
    /// Shared with the server group — an elastic grow re-routes this
    /// client's next push/pull without a respawn.
    ring: SharedRing,
    slots: Arc<RwLock<Vec<NodeId>>>,
    frozen: Arc<AtomicBool>,
    /// Communication filter for pushes.
    pub filter: Filter,
    rng: Rng,
    next_req: u64,
    /// Rows pushed (after filtering).
    pub rows_pushed: u64,
    /// Rows retained by the filter for a later push.
    pub rows_retained: u64,
}

/// Messages a worker may receive that are not pull responses.
#[derive(Debug)]
pub enum ClientEvent {
    /// Fresh rows for a matrix.
    Rows(u8, RowBatch),
    /// A control-plane message (kill/terminate/reroute).
    Control(super::msg::Control),
}

impl PsClient {
    /// Create a client bound to `id` against a server group's ring/slots.
    pub fn new(
        net: SimNet,
        id: NodeId,
        ring: SharedRing,
        slots: Arc<RwLock<Vec<NodeId>>>,
        frozen: Arc<AtomicBool>,
        filter: Filter,
        seed: u64,
    ) -> Self {
        PsClient {
            id,
            net,
            ring,
            slots,
            frozen,
            filter,
            rng: Rng::new(seed),
            next_req: 0,
            rows_pushed: 0,
            rows_retained: 0,
        }
    }

    /// Spin while the manager has the system frozen (server failover).
    /// A killed client stops waiting — its worker exits at the next
    /// liveness check instead of idling forever.
    fn wait_unfrozen(&self) {
        while self.frozen.load(Ordering::SeqCst) && !self.net.is_dead(self.id) {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn node_for(&self, matrix: u8, word: u32) -> NodeId {
        let slot = self.ring.read().unwrap().route(matrix, word);
        self.slots.read().unwrap()[slot as usize]
    }

    /// Drain `replica`'s delta log through the filter and push the
    /// selected row batches to their owning servers. Retained rows are
    /// re-queued into the replica's delta log.
    pub fn push_matrix(&mut self, matrix: u8, replica: &mut CountMatrix) {
        self.wait_unfrozen();
        let deltas = replica.drain_deltas();
        if deltas.is_empty() {
            return;
        }
        let (send, retain) = self.filter.select(deltas, &mut self.rng);
        self.rows_retained += retain.len() as u64;
        for (w, row) in retain {
            replica.requeue_delta(w, row);
        }
        // Group by destination server under one consistent ring view
        // (a concurrent grow lands on the next push).
        let ring = self.ring.read().unwrap().clone();
        let n_slots = ring.slots();
        let mut by_slot: Vec<RowBatch> = (0..n_slots).map(|_| Vec::new()).collect();
        for (w, row) in send {
            by_slot[ring.route(matrix, w) as usize].push((w, row));
            self.rows_pushed += 1;
        }
        for (slot, rows) in by_slot.into_iter().enumerate() {
            if rows.is_empty() {
                continue;
            }
            let node = self.slots.read().unwrap()[slot];
            self.net.send(self.id, node, Payload::Push { matrix, rows });
        }
    }

    /// Fire pull requests for `words` of `matrix` (responses arrive
    /// asynchronously; collect with [`PsClient::drain_responses`]).
    pub fn request_rows(&mut self, matrix: u8, words: &[u32]) {
        self.wait_unfrozen();
        let ring = self.ring.read().unwrap().clone();
        let n_slots = ring.slots();
        let mut by_slot: Vec<Vec<u32>> = (0..n_slots).map(|_| Vec::new()).collect();
        for &w in words {
            by_slot[ring.route(matrix, w) as usize].push(w);
        }
        for (slot, ws) in by_slot.into_iter().enumerate() {
            if ws.is_empty() {
                continue;
            }
            self.next_req += 1;
            let node = self.slots.read().unwrap()[slot];
            self.net.send(
                self.id,
                node,
                Payload::PullReq {
                    matrix,
                    words: ws,
                    req_id: self.next_req,
                },
            );
        }
        let _ = self.node_for(matrix, 0); // keep resolver exercised in debug
    }

    /// Collect everything that has arrived within `wait` (may return
    /// early; never blocks past the deadline).
    pub fn drain_responses(&mut self, wait: Duration) -> Vec<ClientEvent> {
        let mut out = Vec::new();
        let deadline = std::time::Instant::now() + wait;
        loop {
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            match self.net.recv_timeout(self.id, remaining) {
                Some(env) => match env.payload {
                    Payload::PullResp { matrix, rows, .. } => {
                        out.push(ClientEvent::Rows(matrix, rows))
                    }
                    Payload::Control(c) => out.push(ClientEvent::Control(c)),
                    _ => {}
                },
                None => break,
            }
            if std::time::Instant::now() >= deadline {
                break;
            }
        }
        out
    }

    /// Report progress to the scheduler node.
    pub fn report_progress(&self, scheduler: NodeId, shard: usize, iteration: u64, tokens: u64) {
        self.net.send(
            self.id,
            scheduler,
            Payload::Progress {
                shard,
                iteration,
                tokens,
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::network::NetConfig;
    use crate::ps::server::{ServerConfig, ServerGroup};

    #[test]
    fn push_pull_through_client_api() {
        let net = SimNet::new(
            0,
            NetConfig {
                base_latency: Duration::from_micros(50),
                jitter: Duration::ZERO,
                drop_prob: 0.0,
                seed: 5,
            },
        );
        let me = net.add_node();
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: 3,
                row_width: 4,
                ..Default::default()
            },
        );
        let mut client = PsClient::new(
            net.clone(),
            me,
            group.ring.clone(),
            group.slots.clone(),
            group.frozen.clone(),
            Filter::default(),
            7,
        );
        let mut replica = CountMatrix::new(50, 4);
        for w in 0..50u32 {
            replica.inc(w, (w % 4) as usize, (w + 1) as i32);
        }
        client.push_matrix(0, &mut replica);
        assert_eq!(replica.pending_rows(), 0);
        std::thread::sleep(Duration::from_millis(40));

        let words: Vec<u32> = (0..50).collect();
        client.request_rows(0, &words);
        let mut got = std::collections::HashMap::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while got.len() < 50 && std::time::Instant::now() < deadline {
            for ev in client.drain_responses(Duration::from_millis(50)) {
                if let ClientEvent::Rows(0, rows) = ev {
                    for (w, row) in rows {
                        got.insert(w, row);
                    }
                }
            }
        }
        assert_eq!(got.len(), 50, "missing pull responses");
        for w in 0..50u32 {
            let row = &got[&w];
            assert_eq!(row.get((w % 4) as usize), (w + 1) as i32, "row {w}");
        }
        group.shutdown();
    }

    #[test]
    fn filter_retains_rows_in_delta_log() {
        let net = SimNet::new(0, NetConfig::default());
        let me = net.add_node();
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: 1,
                row_width: 2,
                ..Default::default()
            },
        );
        let mut client = PsClient::new(
            net.clone(),
            me,
            group.ring.clone(),
            group.slots.clone(),
            group.frozen.clone(),
            Filter {
                magnitude_fraction: 0.2,
                uniform_prob: 0.0,
                cell_level: false,
            },
            9,
        );
        let mut replica = CountMatrix::new(10, 2);
        for w in 0..10u32 {
            replica.inc(w, 0, 1 + w as i32);
        }
        client.push_matrix(0, &mut replica);
        // 20% of 10 rows sent, the rest retained in the delta log.
        assert_eq!(client.rows_pushed, 2);
        assert_eq!(client.rows_retained, 8);
        assert_eq!(replica.pending_rows(), 8);
        group.shutdown();
    }
}
