//! The client-group scheduler (§4, §5.4, §6).
//!
//! Tracks per-shard progress reports, detects stragglers ("each worker
//! sends a progress report ... the scheduler analyzes the average
//! progress, and decides whether to terminate stragglers and re-assign
//! their tasks"), and implements the termination rule of §6: "we
//! terminate a job when 90% of the workers reach the required number of
//! iterations" — the *curse-of-the-last-reducer* mitigation that produces
//! the shrinking data-point counts in every figure.

use super::msg::NodeId;

/// Per-shard assignment state.
#[derive(Clone, Debug)]
pub struct ShardProgress {
    /// Client currently working the shard.
    pub client: NodeId,
    /// Completed iterations.
    pub iteration: u64,
    /// Tokens sampled under the current assignment.
    pub tokens: u64,
    /// Reassignment count (failovers + straggler kills).
    pub reassignments: u32,
}

/// Scheduler policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Fraction of workers that must reach the target for termination.
    pub completion_quorum: f64,
    /// Iterations behind the *median* before a worker is a straggler.
    pub straggler_lag: u64,
    /// Minimum median progress before straggler kills are considered
    /// (prevents killing everyone at startup).
    pub straggler_warmup: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            completion_quorum: 0.9,
            straggler_lag: 3,
            straggler_warmup: 2,
        }
    }
}

/// The scheduler state machine (driven by the trainer's event loop).
#[derive(Debug)]
pub struct Scheduler {
    cfg: SchedulerConfig,
    target_iterations: u64,
    shards: Vec<ShardProgress>,
}

impl Scheduler {
    /// New scheduler over `assignments[shard] = client`.
    pub fn new(cfg: SchedulerConfig, target_iterations: u64, assignments: Vec<NodeId>) -> Self {
        Scheduler {
            cfg,
            target_iterations,
            shards: assignments
                .into_iter()
                .map(|client| ShardProgress {
                    client,
                    iteration: 0,
                    tokens: 0,
                    reassignments: 0,
                })
                .collect(),
        }
    }

    /// Record a progress report.
    pub fn record(&mut self, shard: usize, client: NodeId, iteration: u64, tokens: u64) {
        if let Some(s) = self.shards.get_mut(shard) {
            // Ignore ghosts: reports from a client that was reassigned away.
            if s.client == client {
                s.iteration = s.iteration.max(iteration);
                if tokens > 0 {
                    s.tokens = tokens;
                }
            }
        }
    }

    /// Re-assign a shard to a new client (failover / straggler kill).
    pub fn reassign(&mut self, shard: usize, new_client: NodeId) {
        if let Some(s) = self.shards.get_mut(shard) {
            s.client = new_client;
            s.reassignments += 1;
        }
    }

    /// Median completed iteration across shards.
    pub fn median_progress(&self) -> u64 {
        if self.shards.is_empty() {
            return 0;
        }
        let mut iters: Vec<u64> = self.shards.iter().map(|s| s.iteration).collect();
        iters.sort_unstable();
        iters[iters.len() / 2]
    }

    /// Shards lagging more than `straggler_lag` behind the median.
    pub fn stragglers(&self) -> Vec<usize> {
        let median = self.median_progress();
        if median < self.cfg.straggler_warmup {
            return Vec::new();
        }
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.iteration + self.cfg.straggler_lag < median
                    && s.iteration < self.target_iterations
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// The 90% rule: fraction of shards at target ≥ quorum?
    pub fn quorum_reached(&self) -> bool {
        if self.shards.is_empty() {
            return true;
        }
        let done = self
            .shards
            .iter()
            .filter(|s| s.iteration >= self.target_iterations)
            .count();
        (done as f64) >= self.cfg.completion_quorum * self.shards.len() as f64
    }

    /// Number of shards that have completed at least `iteration` — the
    /// "number of data points" panel of the paper's figures.
    pub fn datapoints_at(&self, iteration: u64) -> usize {
        self.shards
            .iter()
            .filter(|s| s.iteration >= iteration)
            .count()
    }

    /// Current assignments view.
    pub fn shards(&self) -> &[ShardProgress] {
        &self.shards
    }

    /// Target iteration count.
    pub fn target(&self) -> u64 {
        self.target_iterations
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(n: usize, target: u64) -> Scheduler {
        Scheduler::new(
            SchedulerConfig::default(),
            target,
            (0..n as u32).collect(),
        )
    }

    #[test]
    fn quorum_rule_is_90_percent() {
        let mut s = sched(10, 5);
        for shard in 0..9 {
            s.record(shard, shard as u32, 5, 100);
        }
        assert!(s.quorum_reached(), "9/10 at target = 90% quorum");
        let mut s = sched(10, 5);
        for shard in 0..8 {
            s.record(shard, shard as u32, 5, 100);
        }
        assert!(!s.quorum_reached(), "8/10 < 90%");
    }

    #[test]
    fn straggler_detection_uses_median_lag() {
        let mut s = sched(5, 100);
        for shard in 0..4 {
            s.record(shard, shard as u32, 10, 0);
        }
        s.record(4, 4, 2, 0); // 8 behind the median of 10
        assert_eq!(s.stragglers(), vec![4]);
        // A shard only mildly behind is not a straggler.
        let mut s = sched(5, 100);
        for shard in 0..4 {
            s.record(shard, shard as u32, 10, 0);
        }
        s.record(4, 4, 8, 0);
        assert!(s.stragglers().is_empty());
    }

    #[test]
    fn no_straggler_kills_during_warmup() {
        let mut s = sched(3, 100);
        s.record(0, 0, 1, 0);
        s.record(1, 1, 1, 0);
        // median 1 < warmup 2 → no kills even though shard 2 is at 0.
        assert!(s.stragglers().is_empty());
    }

    #[test]
    fn reassignment_ignores_ghost_reports() {
        let mut s = sched(2, 10);
        s.record(0, 0, 3, 50);
        s.reassign(0, 99);
        s.record(0, 0, 7, 70); // ghost: old client
        assert_eq!(s.shards()[0].iteration, 3);
        s.record(0, 99, 4, 10); // new client
        assert_eq!(s.shards()[0].iteration, 4);
        assert_eq!(s.shards()[0].reassignments, 1);
    }

    #[test]
    fn datapoints_shrink_with_iteration() {
        let mut s = sched(4, 10);
        s.record(0, 0, 10, 0);
        s.record(1, 1, 7, 0);
        s.record(2, 2, 7, 0);
        s.record(3, 3, 2, 0);
        assert_eq!(s.datapoints_at(1), 4);
        assert_eq!(s.datapoints_at(7), 3);
        assert_eq!(s.datapoints_at(10), 1);
    }

    #[test]
    fn completed_shards_are_never_stragglers() {
        let mut s = sched(3, 5);
        s.record(0, 0, 20, 0);
        s.record(1, 1, 20, 0);
        s.record(2, 2, 5, 0); // at target, far behind "median" 20
        assert!(s.stragglers().is_empty());
    }
}
