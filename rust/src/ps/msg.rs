//! Wire messages of the parameter server.
//!
//! Rows are batched (§5.3 "batched communication"): a push/pull carries
//! rows keyed by word id, never individual `(key, value)` pairs. `matrix`
//! distinguishes the statistics a model shares (LDA: one matrix `n_tw`;
//! PDP: `m_tw` and `s_tw`; HDP: `n_tw` and root tables).
//!
//! ## Sparse wire rows
//!
//! Each row travels as a [`RowData`]: `Sparse(Vec<(topic, value)>)` when
//! few cells are non-zero (the common case — a sync window moves a word's
//! tokens between `O(k_w)` topics), `Dense(Box<[i32]>)` past the density
//! break-even (`8·nnz ≥ 4·K`). Push rows carry **deltas**, pull responses
//! carry **absolute** counts; elided cells are 0 in both readings. The
//! producer picks the encoding ([`RowData::from_dense_auto`] /
//! [`crate::sampler::counts::CountMatrix::drain_deltas`]); consumers
//! accept either, so the formats are interchangeable on the wire and
//! [`Payload::wire_bytes`] charges each row its real encoded size —
//! which is what makes the `SimNet` byte metrics reflect the §5.3 claim
//! that batched communication only pays for what changed.

use std::time::Instant;

pub use crate::sampler::counts::RowData;

/// Node identifier (index into the simulated network's inbox table).
pub type NodeId = u32;

/// A batched row set: `(word id, sparse-or-dense row)`.
pub type RowBatch = Vec<(u32, RowData)>;

/// Control-plane commands.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Control {
    /// Hard-kill the receiving node (failure injection / straggler
    /// termination).
    Kill,
    /// Stop cleanly at the end of the current unit of work.
    Terminate,
    /// Server manager → clients: routing epoch changed; re-resolve
    /// servers (after a server failover).
    Reroute,
    /// Session → parked worker: raise the target iteration and resume
    /// sampling. Workers in park mode idle at their target instead of
    /// exiting, so the online loop's very short segments don't pay a
    /// thread respawn + sampler rebuild each time; a raise below the
    /// worker's completed iteration count is stale and ignored.
    RaiseTarget(u64),
}

/// Message payloads.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Client → server: row **deltas** to fold into the store.
    Push {
        /// Which shared matrix.
        matrix: u8,
        /// Batched row deltas.
        rows: RowBatch,
    },
    /// Client → server: request fresh rows.
    PullReq {
        /// Which shared matrix.
        matrix: u8,
        /// Row keys wanted.
        words: Vec<u32>,
        /// Correlation id (echoed in the response).
        req_id: u64,
    },
    /// Server → client: fresh rows.
    PullResp {
        /// Which shared matrix.
        matrix: u8,
        /// Batched row values (absolute, not deltas).
        rows: RowBatch,
        /// Correlation id.
        req_id: u64,
    },
    /// Client → scheduler: progress report (every iteration).
    Progress {
        /// Shard the client is working.
        shard: usize,
        /// Completed iterations.
        iteration: u64,
        /// Tokens sampled so far in this assignment.
        tokens: u64,
    },
    /// Any node → manager: liveness heartbeat.
    Heartbeat,
    /// Coordinator → server: write a slot snapshot into `dir` *now* (the
    /// session checkpoint path — distinct from the periodic barrier-free
    /// cadence, which keeps writing to the configured snapshot dir).
    SnapshotReq {
        /// Directory to write `server_slot{slot}.snap` into.
        dir: std::path::PathBuf,
        /// Checkpoint epoch — a per-session counter identifying *this*
        /// checkpoint attempt. The server seals at most once per epoch
        /// (request retries re-ack the recorded outcome instead of
        /// re-serializing) and echoes it in the ack.
        epoch: u64,
    },
    /// Server → coordinator: checkpoint snapshot written (or failed).
    SnapshotAck {
        /// The responding slot.
        slot: u32,
        /// Whether the write succeeded.
        ok: bool,
        /// The directory the slot wrote into — echoed from the request so
        /// a stale ack from an earlier checkpoint's retry can never
        /// satisfy a later checkpoint into a different directory.
        dir: std::path::PathBuf,
        /// Echoed from the request. The coordinator counts quorum by
        /// `(slot, epoch)`: a duplicate delivery of one ack, or a stale
        /// ack from a previous checkpoint into the *same* directory, can
        /// never satisfy the quorum for a slot that did not serialize in
        /// this epoch.
        epoch: u64,
    },
    /// Elasticity controller → server: the ring is growing to
    /// `new_slots` logical slots — rebuild the ring locally (it is a pure
    /// function of `(slots, vnodes)`), drain every owned row the new
    /// geometry routes to `dest_slot`, ship the rows to `dest` via
    /// [`Payload::Handoff`], and report the accounting with
    /// [`Payload::HandoffAck`].
    HandoffReq {
        /// Slot count of the grown ring.
        new_slots: u32,
        /// Virtual points per slot (unchanged by a grow).
        vnodes: u32,
        /// Node hosting the new slot (handoff destination).
        dest: NodeId,
        /// The new slot id (always `new_slots - 1` for a grow).
        dest_slot: u32,
    },
    /// Server → server: **absolute** rows whose ownership moved to the
    /// receiver under the grown ring. The receiver installs them verbatim
    /// (its store holds nothing for these keys yet) and receipts the
    /// batch to `ack_to`.
    Handoff {
        /// Which shared matrix.
        matrix: u8,
        /// Batched row values (absolute, like a pull response).
        rows: RowBatch,
        /// Controller node to receipt the arrival to.
        ack_to: NodeId,
    },
    /// Server → controller: handoff accounting. Sent once by each
    /// draining slot (with its `moved`/`total` row counts) and once per
    /// received batch by the destination slot (receipts, `total = 0`) —
    /// together they let the controller both assert the ≈1/(N+1)
    /// movement bound and confirm every shipped row arrived.
    HandoffAck {
        /// The reporting slot.
        slot: u32,
        /// Rows shipped (drain report) or received (receipt).
        moved: u64,
        /// Rows owned before the drain (drain report; 0 in receipts).
        total: u64,
    },
    /// Control-plane command.
    Control(Control),
}

impl Payload {
    /// Approximate wire size in bytes (for the network-traffic metrics):
    /// 16 per message + 4 per word key + each row's encoded size
    /// ([`RowData::wire_bytes`] — 4 bytes/cell dense, 8 bytes/pair sparse).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Push { rows, .. }
            | Payload::PullResp { rows, .. }
            | Payload::Handoff { rows, .. } => {
                rows.iter().map(|(_, r)| 4 + r.wire_bytes()).sum::<u64>() + 16
            }
            Payload::PullReq { words, .. } => 16 + 4 * words.len() as u64,
            Payload::Progress { .. } => 32,
            Payload::HandoffReq { .. } | Payload::HandoffAck { .. } => 24,
            Payload::SnapshotReq { dir, .. } | Payload::SnapshotAck { dir, .. } => {
                24 + dir.as_os_str().len() as u64
            }
            Payload::Heartbeat | Payload::Control(_) => 8,
        }
    }
}

/// A routed message with its simulated delivery time.
#[derive(Debug)]
pub struct Envelope {
    /// Sender node.
    pub from: NodeId,
    /// Destination node.
    pub to: NodeId,
    /// Simulated arrival time (the transport delays delivery until then).
    pub deliver_at: Instant,
    /// Monotonic sequence for deterministic tie-breaking.
    pub seq: u64,
    /// The payload.
    pub payload: Payload,
}

impl PartialEq for Envelope {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Envelope {}
impl PartialOrd for Envelope {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Envelope {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Earliest delivery first (BinaryHeap is a max-heap → reverse).
        other
            .deliver_at
            .cmp(&self.deliver_at)
            .then(other.seq.cmp(&self.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn envelope_orders_by_delivery_time() {
        let now = Instant::now();
        let mk = |dt_ms: u64, seq: u64| Envelope {
            from: 0,
            to: 1,
            deliver_at: now + Duration::from_millis(dt_ms),
            seq,
            payload: Payload::Heartbeat,
        };
        let mut heap = std::collections::BinaryHeap::new();
        heap.push(mk(30, 1));
        heap.push(mk(10, 2));
        heap.push(mk(20, 3));
        assert_eq!(heap.pop().unwrap().seq, 2);
        assert_eq!(heap.pop().unwrap().seq, 3);
        assert_eq!(heap.pop().unwrap().seq, 1);
    }

    #[test]
    fn wire_bytes_accounts_rows() {
        let p = Payload::Push {
            matrix: 0,
            rows: vec![
                (1, RowData::Dense(vec![0i32; 10].into())),
                (2, RowData::Dense(vec![0i32; 10].into())),
            ],
        };
        assert_eq!(p.wire_bytes(), 16 + 2 * (4 + 5 + 40));
    }

    #[test]
    fn wire_bytes_sparse_rows_are_cheaper() {
        let k = 256;
        let dense = Payload::Push {
            matrix: 0,
            rows: vec![(1, RowData::Dense(vec![1i32; k].into()))],
        };
        let sparse = Payload::Push {
            matrix: 0,
            rows: vec![(1, RowData::Sparse(vec![(3, 1), (200, -1)]))],
        };
        assert_eq!(dense.wire_bytes(), 16 + 4 + 5 + 4 * k as u64);
        assert_eq!(sparse.wire_bytes(), 16 + 4 + 5 + 8 * 2);
        assert!(sparse.wire_bytes() * 2 < dense.wire_bytes());
    }
}
