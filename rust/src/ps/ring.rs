//! Chord-style consistent hashing (§4: "(key,value) pairs are partitioned
//! into server nodes by consistent hashing in the form of a Chord-style
//! layout [18]").
//!
//! Keys are `(matrix, word)` pairs; each of the `S` logical server slots
//! owns the arc between its virtual points. Consistent hashing keeps the
//! key→slot map stable when slots are *re-bound* to replacement physical
//! nodes (failover rebinds a slot; it does not move keys).

use crate::util::rng::splitmix64;

/// A ring shared across threads and swappable at runtime (elastic
/// membership): clients take a read guard per send, the grow path swaps
/// in the grown ring under the write lock while the system is frozen.
pub type SharedRing = std::sync::Arc<std::sync::RwLock<Ring>>;

/// Consistent-hash ring over logical server slots.
#[derive(Clone, Debug)]
pub struct Ring {
    /// Sorted `(point, slot)` pairs.
    points: Vec<(u64, u32)>,
    slots: usize,
}

impl Ring {
    /// Build a ring of `slots` logical servers with `vnodes` virtual
    /// points each (more vnodes → better balance).
    pub fn new(slots: usize, vnodes: usize) -> Self {
        assert!(slots > 0);
        let mut points = Vec::with_capacity(slots * vnodes);
        for s in 0..slots as u32 {
            let mut h = 0x5EED ^ (s as u64).wrapping_mul(0x9E3779B97F4A7C15);
            for _ in 0..vnodes {
                points.push((splitmix64(&mut h), s));
            }
        }
        points.sort_unstable();
        points.dedup_by_key(|p| p.0);
        Ring { points, slots }
    }

    /// Number of logical slots.
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Hash a `(matrix, word)` key.
    #[inline]
    pub fn key_hash(matrix: u8, word: u32) -> u64 {
        let mut h = ((matrix as u64) << 32) | word as u64;
        splitmix64(&mut h)
    }

    /// Route a key to its owning slot.
    #[inline]
    pub fn route(&self, matrix: u8, word: u32) -> u32 {
        let h = Self::key_hash(matrix, word);
        // First point clockwise from h (binary search).
        let idx = self.points.partition_point(|&(p, _)| p < h);
        let idx = if idx == self.points.len() { 0 } else { idx };
        self.points[idx].1
    }

    /// Per-slot key counts over the words `0..vocab` of `matrix` — the
    /// load-balance diagnostic behind the serving router's partition
    /// report (`serve --replicas N`) and the ring property tests.
    pub fn spread(&self, matrix: u8, vocab: usize) -> Vec<usize> {
        let mut counts = vec![0usize; self.slots];
        for w in 0..vocab as u32 {
            counts[self.route(matrix, w) as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_are_stable_and_in_range() {
        let r = Ring::new(8, 64);
        for w in 0..10_000u32 {
            let s1 = r.route(0, w);
            let s2 = r.route(0, w);
            assert_eq!(s1, s2);
            assert!((s1 as usize) < 8);
        }
    }

    #[test]
    fn load_is_balanced() {
        let r = Ring::new(8, 128);
        let counts = r.spread(0, 80_000);
        assert_eq!(counts.iter().sum::<usize>(), 80_000);
        let mean = 10_000.0;
        for (s, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > 0.5 * mean && (c as f64) < 1.6 * mean,
                "slot {s} owns {c} keys"
            );
        }
    }

    #[test]
    fn matrices_hash_independently() {
        let r = Ring::new(4, 64);
        let same = (0..1000u32)
            .filter(|&w| r.route(0, w) == r.route(1, w))
            .count();
        // ≈ 1/4 collide by chance; far fewer than all.
        assert!(same < 500, "matrix id ignored in routing? ({same})");
    }

    #[test]
    fn growing_the_ring_only_moves_keys_to_the_new_slot() {
        // Slot s's virtual points depend only on s, so `Ring::new(n+1,v)`
        // is `Ring::new(n,v)` plus the new slot's points: a key either
        // keeps its owner or moves to slot n — never between old slots.
        // This is the consistent-hashing property the serving router's
        // resize bound (~1/(n+1) of the vocabulary remapped) rests on.
        for n in 1..6usize {
            let old = Ring::new(n, 64);
            let new = Ring::new(n + 1, 64);
            let mut moved = 0usize;
            for w in 0..20_000u32 {
                let a = old.route(0, w);
                let b = new.route(0, w);
                if a != b {
                    assert_eq!(b, n as u32, "key moved between old slots");
                    moved += 1;
                }
            }
            let frac = moved as f64 / 20_000.0;
            let expect = 1.0 / (n + 1) as f64;
            assert!(
                frac > 0.35 * expect && frac < 2.5 * expect,
                "{n}→{} remapped fraction {frac:.4} vs expected ≈{expect:.4}",
                n + 1
            );
        }
    }

    #[test]
    fn single_slot_routes_everything() {
        let r = Ring::new(1, 4);
        for w in 0..100u32 {
            assert_eq!(r.route(3, w), 0);
        }
    }
}
