//! Constraint rules and their proximal (nearest-consistent-value)
//! projections.
//!
//! `C₁` rules constrain two same-shaped parameter collections `(A, B)`
//! cell-wise — here `(s_tw, m_tw)` ("tables", "customers"). `C₂` rules
//! tie an aggregate to its parts (`B = Σᵢ Aᵢ` — the `n_t` totals), which
//! clients maintain by re-deriving the aggregate (§5.5: "easily maintained
//! by deriving the aggregation parameter from its counterparts").

/// A cell-wise rule over a pair of parameters `(a, b)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairRule {
    /// The PDP/HDP table polytope: `b ≥ 0`, `0 ≤ a ≤ b`, `b>0 ⇒ a>0`
    /// (`a` = tables `s`, `b` = customers `m`).
    TablePolytope,
    /// Both parameters merely non-negative.
    NonNegative,
}

/// An aggregate rule: `total = Σ rows` for one matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggRule {
    /// Re-derive per-topic totals from rows.
    RederiveTotals,
}

impl PairRule {
    /// Does `(a, b)` satisfy the rule?
    #[inline]
    pub fn holds(&self, a: i32, b: i32) -> bool {
        match self {
            PairRule::TablePolytope => b >= 0 && a >= 0 && a <= b && !(b > 0 && a == 0),
            PairRule::NonNegative => a >= 0 && b >= 0,
        }
    }
}

/// Proximal projection of `(a, b)` onto the rule's feasible set:
/// the feasible point minimizing `|a'−a| + |b'−b|`, preferring to move
/// `a` alone when possible (Algorithm 1's two-tier `argmin`: first try
/// `A_i' : c(A_i', B_i)`, only then move both).
#[inline]
pub fn project_pair(rule: PairRule, a: i32, b: i32) -> (i32, i32) {
    if rule.holds(a, b) {
        return (a, b);
    }
    match rule {
        PairRule::NonNegative => (a.max(0), b.max(0)),
        PairRule::TablePolytope => {
            // Tier 1: fix a for the given b (b == 0 → a = 0; b > 0 →
            // a ∈ [1, b]).
            if b >= 0 {
                let a1 = if b == 0 { 0 } else { a.clamp(1, b) };
                return (a1, b);
            }
            // Tier 2: b < 0 — move both to the nearest feasible point,
            // which is (0, 0) (or (1, 1) when a is large, but (max(a,0)
            // clamped) — L1-nearest: b→0 costs |b|; then a→0 costs |a|;
            // alternatively b→max(1,?) costs more. (0,0) unless a ≥ 1,
            // where (1,1) costs |b|+1+|a−1| vs (0,0) costs |b|+|a| — for
            // a ≥ 1, (1,1) is never worse and keeps the table occupied.
            if a >= 1 {
                (1, 1)
            } else {
                (0, 0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasible_points_are_fixed() {
        for (a, b) in [(0, 0), (1, 1), (1, 5), (3, 3), (2, 7)] {
            assert!(PairRule::TablePolytope.holds(a, b));
            assert_eq!(project_pair(PairRule::TablePolytope, a, b), (a, b));
        }
    }

    #[test]
    fn fig3_example_customers_without_table() {
        // Fig 3 left: m=3, s=0 (update zeroed tables) → s must become 1.
        assert_eq!(project_pair(PairRule::TablePolytope, 0, 3), (1, 3));
    }

    #[test]
    fn fig3_example_tables_exceed_customers() {
        // Fig 3 right: m=1, s=2 → s clamps to m.
        assert_eq!(project_pair(PairRule::TablePolytope, 2, 1), (1, 1));
    }

    #[test]
    fn zero_customers_forces_zero_tables() {
        assert_eq!(project_pair(PairRule::TablePolytope, 2, 0), (0, 0));
    }

    #[test]
    fn negative_counts_are_repaired() {
        assert_eq!(project_pair(PairRule::TablePolytope, -3, 4), (1, 4));
        assert_eq!(project_pair(PairRule::TablePolytope, 2, -1), (1, 1));
        assert_eq!(project_pair(PairRule::TablePolytope, -2, -5), (0, 0));
        assert_eq!(project_pair(PairRule::NonNegative, -1, -2), (0, 0));
    }

    #[test]
    fn projection_is_idempotent() {
        for a in -4..6 {
            for b in -4..6 {
                let (a1, b1) = project_pair(PairRule::TablePolytope, a, b);
                assert!(
                    PairRule::TablePolytope.holds(a1, b1),
                    "({a},{b}) → ({a1},{b1}) infeasible"
                );
                assert_eq!(
                    project_pair(PairRule::TablePolytope, a1, b1),
                    (a1, b1),
                    "not idempotent at ({a},{b})"
                );
            }
        }
    }

    #[test]
    fn projection_is_l1_minimal() {
        // Exhaustive check against brute force on a small grid.
        for a in -4..8 {
            for b in -4..8 {
                let (a1, b1) = project_pair(PairRule::TablePolytope, a, b);
                let cost = (a1 - a).abs() + (b1 - b).abs();
                let mut best = i32::MAX;
                for aa in -1..12 {
                    for bb in -1..12 {
                        if PairRule::TablePolytope.holds(aa, bb) {
                            best = best.min((aa - a).abs() + (bb - b).abs());
                        }
                    }
                }
                assert_eq!(cost, best, "({a},{b}) projected to ({a1},{b1}) cost {cost} best {best}");
            }
        }
    }
}
