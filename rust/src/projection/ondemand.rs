//! **Algorithm 3 — On-demand projection on the server.**
//!
//! "Performed on the server for every update ... must be done in
//! real-time and requires high performance." The server group installs
//! this hook; after folding a pushed row delta into `(matrix, word)`, the
//! hook projects that row against its paired matrix's row so the store
//! never serves a violating pair.

use super::constraint::{project_pair, PairRule};
use crate::ps::snapshot::Store;
use crate::sampler::counts::HybridRow;

/// Server-side projection hook over `(a_matrix, b_matrix)` pairs.
#[derive(Clone, Debug)]
pub struct OnDemandProjection {
    /// `(a, b, rule)` triples — `a` is the table-like matrix, `b` the
    /// customer-like matrix.
    pub pairs: Vec<(u8, u8, PairRule)>,
}

impl OnDemandProjection {
    /// Hook for the PDP layout (`m` = matrix 0, `s` = matrix 1).
    pub fn pdp() -> Self {
        OnDemandProjection {
            pairs: vec![(1, 0, PairRule::TablePolytope)],
        }
    }

    /// Hook applying plain non-negativity to every matrix (LDA).
    pub fn nonneg() -> Self {
        OnDemandProjection { pairs: Vec::new() }
    }

    /// Correct the row pair containing `(touched_matrix, word)`.
    /// Returns the number of corrected cells.
    pub fn correct(&self, store: &mut Store, touched_matrix: u8, word: u32) -> u64 {
        let mut corrections = 0u64;
        for &(am, bm, rule) in &self.pairs {
            if touched_matrix != am && touched_matrix != bm {
                continue;
            }
            // Only the union of non-zero topics can violate:
            // `project_pair(rule, 0, 0) == (0, 0)` for every rule, so the
            // scan is O(nnz) instead of O(K). Absent rows = all zeros.
            let k = store.get(&(am, word)).map_or(0, |r| r.k()).max(
                store.get(&(bm, word)).map_or(0, |r| r.k()),
            );
            if k == 0 {
                continue;
            }
            let mut topics: Vec<u32> = Vec::new();
            if let Some(r) = store.get(&(am, word)) {
                r.for_each(|t, _| topics.push(t));
            }
            if let Some(r) = store.get(&(bm, word)) {
                r.for_each(|t, _| topics.push(t));
            }
            topics.sort_unstable();
            topics.dedup();
            if topics.is_empty() {
                continue;
            }
            let mut a_new = store
                .get(&(am, word))
                .cloned()
                .unwrap_or_else(|| HybridRow::new(k));
            let mut b_new = store
                .get(&(bm, word))
                .cloned()
                .unwrap_or_else(|| HybridRow::new(k));
            a_new.ensure_width(k);
            b_new.ensure_width(k);
            let mut changed = false;
            for &t in &topics {
                let t = t as usize;
                let (a0, b0) = (a_new.get(t), b_new.get(t));
                let (a1, b1) = project_pair(rule, a0, b0);
                if a1 != a0 {
                    a_new.set(t, a1);
                    corrections += 1;
                    changed = true;
                }
                if b1 != b0 {
                    b_new.set(t, b1);
                    corrections += 1;
                    changed = true;
                }
            }
            if changed {
                store.insert((am, word), a_new);
                store.insert((bm, word), b_new);
            }
        }
        corrections
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corrects_violating_store_rows() {
        let mut store = Store::new();
        store.insert((0, 5), vec![3, 0, 1].into()); // m
        store.insert((1, 5), vec![0, 2, 1].into()); // s: violations at t=0 (m>0,s=0) and t=1 (s>m)
        let p = OnDemandProjection::pdp();
        let n = p.correct(&mut store, 0, 5);
        assert!(n >= 2);
        assert_eq!(store[&(1, 5)], HybridRow::from(vec![1, 0, 1]));
        assert_eq!(store[&(0, 5)], HybridRow::from(vec![3, 0, 1]));
    }

    #[test]
    fn absent_partner_row_is_created_when_needed() {
        let mut store = Store::new();
        store.insert((0, 9), vec![4, 0].into()); // customers, no table row at all
        let p = OnDemandProjection::pdp();
        let n = p.correct(&mut store, 0, 9);
        assert_eq!(n, 1);
        assert_eq!(store[&(1, 9)], HybridRow::from(vec![1, 0]));
    }

    #[test]
    fn untouched_matrices_are_ignored() {
        let mut store = Store::new();
        store.insert((7, 1), vec![-5].into());
        let p = OnDemandProjection::pdp();
        assert_eq!(p.correct(&mut store, 7, 1), 0);
        assert_eq!(store[&(7, 1)], HybridRow::from(vec![-5]));
    }

    #[test]
    fn clean_rows_cost_nothing() {
        let mut store = Store::new();
        store.insert((0, 2), vec![5, 2].into());
        store.insert((1, 2), vec![2, 1].into());
        let p = OnDemandProjection::pdp();
        assert_eq!(p.correct(&mut store, 1, 2), 0);
    }
}
