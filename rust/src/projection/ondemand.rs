//! **Algorithm 3 — On-demand projection on the server.**
//!
//! "Performed on the server for every update ... must be done in
//! real-time and requires high performance." The server group installs
//! this hook; after folding a pushed row delta into `(matrix, word)`, the
//! hook projects that row against its paired matrix's row so the store
//! never serves a violating pair.

use super::constraint::{project_pair, PairRule};
use crate::ps::snapshot::Store;

/// Server-side projection hook over `(a_matrix, b_matrix)` pairs.
#[derive(Clone, Debug)]
pub struct OnDemandProjection {
    /// `(a, b, rule)` triples — `a` is the table-like matrix, `b` the
    /// customer-like matrix.
    pub pairs: Vec<(u8, u8, PairRule)>,
}

impl OnDemandProjection {
    /// Hook for the PDP layout (`m` = matrix 0, `s` = matrix 1).
    pub fn pdp() -> Self {
        OnDemandProjection {
            pairs: vec![(1, 0, PairRule::TablePolytope)],
        }
    }

    /// Hook applying plain non-negativity to every matrix (LDA).
    pub fn nonneg() -> Self {
        OnDemandProjection { pairs: Vec::new() }
    }

    /// Correct the row pair containing `(touched_matrix, word)`.
    /// Returns the number of corrected cells.
    pub fn correct(&self, store: &mut Store, touched_matrix: u8, word: u32) -> u64 {
        let mut corrections = 0u64;
        for &(am, bm, rule) in &self.pairs {
            if touched_matrix != am && touched_matrix != bm {
                continue;
            }
            // Both rows must exist to be comparable; absent = all zeros.
            let a_row = store.get(&(am, word)).cloned().unwrap_or_default();
            let b_row = store.get(&(bm, word)).cloned().unwrap_or_default();
            let k = a_row.len().max(b_row.len());
            if k == 0 {
                continue;
            }
            let mut a_new = a_row.clone();
            let mut b_new = b_row.clone();
            a_new.resize(k, 0);
            b_new.resize(k, 0);
            let mut changed = false;
            for t in 0..k {
                let (a1, b1) = project_pair(rule, a_new[t], b_new[t]);
                if a1 != a_new[t] {
                    a_new[t] = a1;
                    corrections += 1;
                    changed = true;
                }
                if b1 != b_new[t] {
                    b_new[t] = b1;
                    corrections += 1;
                    changed = true;
                }
            }
            if changed {
                store.insert((am, word), a_new);
                store.insert((bm, word), b_new);
            }
        }
        corrections
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corrects_violating_store_rows() {
        let mut store = Store::new();
        store.insert((0, 5), vec![3, 0, 1]); // m
        store.insert((1, 5), vec![0, 2, 1]); // s: violations at t=0 (m>0,s=0) and t=1 (s>m)
        let p = OnDemandProjection::pdp();
        let n = p.correct(&mut store, 0, 5);
        assert!(n >= 2);
        assert_eq!(store[&(1, 5)], vec![1, 0, 1]);
        assert_eq!(store[&(0, 5)], vec![3, 0, 1]);
    }

    #[test]
    fn absent_partner_row_is_created_when_needed() {
        let mut store = Store::new();
        store.insert((0, 9), vec![4, 0]); // customers, no table row at all
        let p = OnDemandProjection::pdp();
        let n = p.correct(&mut store, 0, 9);
        assert_eq!(n, 1);
        assert_eq!(store[&(1, 9)], vec![1, 0]);
    }

    #[test]
    fn untouched_matrices_are_ignored() {
        let mut store = Store::new();
        store.insert((7, 1), vec![-5]);
        let p = OnDemandProjection::pdp();
        assert_eq!(p.correct(&mut store, 7, 1), 0);
        assert_eq!(store[&(7, 1)], vec![-5]);
    }

    #[test]
    fn clean_rows_cost_nothing() {
        let mut store = Store::new();
        store.insert((0, 2), vec![5, 2]);
        store.insert((1, 2), vec![2, 1]);
        let p = OnDemandProjection::pdp();
        assert_eq!(p.correct(&mut store, 1, 2), 0);
    }
}
