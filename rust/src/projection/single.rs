//! **Algorithm 1 — Simple Single Machine Projection.**
//!
//! At the end of each iteration, one designated client sweeps every
//! parameter pair, replaces violating cells with their nearest consistent
//! values, and *sends the corrections as updates* (the `SendUpdate` calls
//! of the pseudo-code — here: the corrections land in the replicas' delta
//! logs, so the next push propagates them to the servers). `C₂`
//! aggregates are re-derived afterwards.

use super::constraint::{project_pair, AggRule, PairRule};
use crate::sampler::counts::CountMatrix;

/// Algorithm-1 executor.
#[derive(Clone, Debug)]
pub struct SingleMachineProjection {
    /// The C₁ rule applied to `(a, b)` matrix pairs.
    pub rule: PairRule,
    /// The C₂ rule (aggregate re-derivation).
    pub agg: AggRule,
}

impl Default for SingleMachineProjection {
    fn default() -> Self {
        SingleMachineProjection {
            rule: PairRule::TablePolytope,
            agg: AggRule::RederiveTotals,
        }
    }
}

impl SingleMachineProjection {
    /// Sweep all words of the pair `(a, b)` — in PDP terms `(s_tw, m_tw)`
    /// — projecting violations. Returns the number of corrected cells.
    ///
    /// `words` limits the sweep (Algorithm 2 passes this client's
    /// partition; Algorithm 1 passes everything).
    pub fn project_words(
        &self,
        a: &mut CountMatrix,
        b: &mut CountMatrix,
        words: impl Iterator<Item = u32>,
    ) -> u64 {
        let k = a.k();
        let mut corrections = 0u64;
        for w in words {
            for t in 0..k {
                let av = a.get(w, t);
                let bv = b.get(w, t);
                let (a1, b1) = project_pair(self.rule, av, bv);
                if a1 != av {
                    // The correction is itself an update (SendUpdate).
                    a.inc(w, t, a1 - av);
                    corrections += 1;
                }
                if b1 != bv {
                    b.inc(w, t, b1 - bv);
                    corrections += 1;
                }
            }
        }
        if corrections > 0 {
            match self.agg {
                AggRule::RederiveTotals => {
                    a.rebuild_totals();
                    b.rebuild_totals();
                }
            }
        }
        corrections
    }

    /// Algorithm 1 proper: sweep *all* words.
    pub fn project_all(&self, a: &mut CountMatrix, b: &mut CountMatrix) -> u64 {
        let vocab = a.vocab() as u32;
        self.project_words(a, b, 0..vocab)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn violating_pair() -> (CountMatrix, CountMatrix) {
        let mut s = CountMatrix::new(4, 3);
        let mut m = CountMatrix::new(4, 3);
        // word 0: consistent (s=1, m=2)
        s.inc_local(0, 0, 1);
        m.inc_local(0, 0, 2);
        // word 1: customers without tables (m=3, s=0)
        m.inc_local(1, 1, 3);
        // word 2: tables exceed customers (s=4, m=1)
        s.inc_local(2, 2, 4);
        m.inc_local(2, 2, 1);
        // word 3: negative customer count (m=-2, s=1)
        m.inc_local(3, 0, -2);
        s.inc_local(3, 0, 1);
        (s, m)
    }

    #[test]
    fn sweep_repairs_all_violations() {
        let (mut s, mut m) = violating_pair();
        let proj = SingleMachineProjection::default();
        let n = proj.project_all(&mut s, &mut m);
        assert!(n >= 3, "expected ≥3 corrections, got {n}");
        for w in 0..4u32 {
            for t in 0..3 {
                assert!(
                    PairRule::TablePolytope.holds(s.get(w, t), m.get(w, t)),
                    "({w},{t}) still violating: s={} m={}",
                    s.get(w, t),
                    m.get(w, t)
                );
            }
        }
        // Specific repairs.
        assert_eq!(s.get(1, 1), 1, "tables opened for orphan customers");
        assert_eq!(s.get(2, 2), 1, "tables clamped to customers");
        assert_eq!(m.get(3, 0), 1, "negative customers repaired");
    }

    #[test]
    fn corrections_become_pushable_deltas() {
        let (mut s, mut m) = violating_pair();
        // Simulate flushed state: clear the init deltas first.
        let _ = s.drain_deltas();
        let _ = m.drain_deltas();
        let proj = SingleMachineProjection::default();
        proj.project_all(&mut s, &mut m);
        // The corrections must be sitting in the delta logs (SendUpdate).
        assert!(s.pending_rows() + m.pending_rows() > 0);
    }

    #[test]
    fn totals_rederived_after_sweep() {
        let (mut s, mut m) = violating_pair();
        let proj = SingleMachineProjection::default();
        proj.project_all(&mut s, &mut m);
        let mut expect_s = vec![0i64; 3];
        let mut expect_m = vec![0i64; 3];
        for w in 0..4u32 {
            for t in 0..3 {
                expect_s[t] += s.get(w, t) as i64;
                expect_m[t] += m.get(w, t) as i64;
            }
        }
        assert_eq!(s.totals(), &expect_s[..]);
        assert_eq!(m.totals(), &expect_m[..]);
    }

    #[test]
    fn clean_state_is_untouched() {
        let mut s = CountMatrix::new(4, 2);
        let mut m = CountMatrix::new(4, 2);
        s.inc_local(0, 0, 2);
        m.inc_local(0, 0, 5);
        let proj = SingleMachineProjection::default();
        assert_eq!(proj.project_all(&mut s, &mut m), 0);
    }
}
