//! **Algorithm 2 — Distributed Projection** (the variant the paper
//! reports: "the second approach works particularly well in practice").
//!
//! Correction tasks are partitioned across clients by parameter id
//! ("randomly allocate parameter correction tasks to each client ... such
//! that correction task of each ID is only assigned to one client"); at
//! the end of each iteration every client sweeps *its own* partition with
//! the Algorithm-1 kernel and pushes the corrections like any other
//! update.

use super::single::SingleMachineProjection;
use crate::sampler::counts::CountMatrix;
use crate::util::rng::splitmix64;

/// Algorithm-2 executor for one client.
#[derive(Clone, Debug)]
pub struct DistributedProjection {
    inner: SingleMachineProjection,
    /// This client's index within the group.
    pub client_idx: usize,
    /// Total clients sharing the sweep.
    pub n_clients: usize,
    /// Salt for the random (but agreed) id → client allocation.
    pub salt: u64,
}

impl DistributedProjection {
    /// New executor for client `client_idx` of `n_clients`.
    pub fn new(client_idx: usize, n_clients: usize, salt: u64) -> Self {
        assert!(n_clients > 0 && client_idx < n_clients);
        DistributedProjection {
            inner: SingleMachineProjection::default(),
            client_idx,
            n_clients,
            salt,
        }
    }

    /// Is word `w`'s correction task allocated to this client?
    #[inline]
    pub fn owns(&self, w: u32) -> bool {
        let mut h = self.salt ^ (w as u64).wrapping_mul(0x9E3779B97F4A7C15);
        (splitmix64(&mut h) as usize % self.n_clients) == self.client_idx
    }

    /// End-of-iteration sweep over this client's partition.
    pub fn project_owned(&self, a: &mut CountMatrix, b: &mut CountMatrix) -> u64 {
        let vocab = a.vocab() as u32;
        let owned: Vec<u32> = (0..vocab).filter(|&w| self.owns(w)).collect();
        self.inner.project_words(a, b, owned.into_iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::constraint::PairRule;

    #[test]
    fn partition_is_exact_and_exhaustive() {
        let n = 5;
        let mut owners = vec![0usize; 1000];
        for c in 0..n {
            let p = DistributedProjection::new(c, n, 42);
            for w in 0..1000u32 {
                if p.owns(w) {
                    owners[w as usize] += 1;
                }
            }
        }
        assert!(
            owners.iter().all(|&o| o == 1),
            "every id must belong to exactly one client"
        );
    }

    #[test]
    fn partition_is_roughly_balanced() {
        let n = 4;
        let mut counts = vec![0usize; n];
        for c in 0..n {
            let p = DistributedProjection::new(c, n, 7);
            counts[c] = (0..10_000u32).filter(|&w| p.owns(w)).count();
        }
        for &c in &counts {
            assert!((1800..3200).contains(&c), "unbalanced partition {counts:?}");
        }
    }

    #[test]
    fn union_of_client_sweeps_repairs_everything() {
        let n_clients = 3;
        let vocab = 60;
        let k = 4;
        let mut s = CountMatrix::new(vocab, k);
        let mut m = CountMatrix::new(vocab, k);
        // Scatter violations everywhere.
        for w in 0..vocab as u32 {
            m.inc_local(w, (w % k as u32) as usize, 3); // customers, no tables
            s.inc_local(w, ((w + 1) % k as u32) as usize, 2); // tables, no customers
        }
        for c in 0..n_clients {
            let p = DistributedProjection::new(c, n_clients, 99);
            p.project_owned(&mut s, &mut m);
        }
        for w in 0..vocab as u32 {
            for t in 0..k {
                assert!(
                    PairRule::TablePolytope.holds(s.get(w, t), m.get(w, t)),
                    "({w},{t}) unrepaired after all clients swept"
                );
            }
        }
    }

    #[test]
    fn disjoint_sweeps_do_not_double_correct() {
        let vocab = 40;
        let mut s = CountMatrix::new(vocab, 2);
        let mut m = CountMatrix::new(vocab, 2);
        for w in 0..vocab as u32 {
            m.inc_local(w, 0, 1);
        } // each needs one table
        let p0 = DistributedProjection::new(0, 2, 1);
        let p1 = DistributedProjection::new(1, 2, 1);
        let c0 = p0.project_owned(&mut s, &mut m);
        let c1 = p1.project_owned(&mut s, &mut m);
        assert_eq!(c0 + c1, vocab as u64, "exactly one correction per word");
    }
}
