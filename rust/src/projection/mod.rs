//! Parameter projection for constraint-violation resolution (§5.5).
//!
//! Relaxed consistency lets clients' delta streams interleave into states
//! that violate the models' polytope constraints (Fig 3): in PDP the
//! word-topic-table counts must satisfy `0 ≤ s_tw ≤ m_tw` and
//! `m_tw > 0 ⇒ s_tw > 0`; HDP has the analogous relation between root
//! table counts and item counts. Sampling from violating statistics
//! "may easily produce NaN, infinite, or other unstable probabilities" —
//! Fig 8 shows the divergence.
//!
//! The fix is a **proximal projection**: round parameters to their nearest
//! consistent values. Three placements are implemented, exactly the
//! paper's three algorithms:
//!
//! * [`single`] — **Algorithm 1**: one designated client sweeps all
//!   parameters at the end of each iteration (batch).
//! * [`distributed`] — **Algorithm 2**: the sweep is partitioned across
//!   clients by parameter id (the variant the paper reports).
//! * [`ondemand`] — **Algorithm 3**: the server corrects every touched row
//!   in real time as updates arrive.

pub mod constraint;
pub mod distributed;
pub mod ondemand;
pub mod single;

pub use constraint::{project_pair, AggRule, PairRule};
pub use distributed::DistributedProjection;
pub use ondemand::OnDemandProjection;
pub use single::SingleMachineProjection;
