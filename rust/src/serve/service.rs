//! The inference service: a bounded request queue drained by a pool of
//! worker threads in micro-batches.
//!
//! Deployment shape for the "heavy traffic from millions of users" side
//! of the roadmap: callers [`submit`](InferenceService::submit) documents
//! and get a reply channel; N workers pull up to `max_batch` queued jobs
//! at a time (one lock acquisition amortized over the batch) and fold
//! each document in against the generation the worker pinned from the
//! shared [`QueryBackend`] at the top of the batch — a single
//! [`ServingHandle`](super::handle::ServingHandle) or a multi-replica
//! [`ReplicaSet`](super::router::ReplicaSet); the pool is agnostic. The
//! queue is bounded — a full queue applies back-pressure by blocking
//! submitters instead of growing without limit.
//!
//! The backend indirection is what makes hot reload safe: a
//! [`reload`](super::handle::ServingHandle::reload) swap (or a set-wide
//! replica commit) never touches the queue, so requests in flight across
//! a swap are all answered (by whichever generation their batch pinned)
//! and each [`InferResult`] reports the generation that served it.
//!
//! Results are deterministic per request for a fixed generation: each
//! job's RNG stream is derived from `(service seed, request sequence
//! number)`, so the answer does not depend on which worker ran it or how
//! batches formed.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use super::handle::QueryBackend;
use super::infer::{InferConfig, InferResult};
use crate::util::rng::{Rng, Zipf};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads.
    pub workers: usize,
    /// Bounded queue capacity (back-pressure beyond this).
    pub queue_capacity: usize,
    /// Jobs a worker claims per queue access.
    pub max_batch: usize,
    /// Seed for the per-request RNG streams.
    pub seed: u64,
    /// Fold-in chain settings.
    pub infer: InferConfig,
    /// `serve --watch` snapshot-poll interval in milliseconds (the
    /// wire server and the CLI watcher both read it from here).
    pub watch_interval_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            queue_capacity: 1024,
            max_batch: 32,
            seed: 42,
            infer: InferConfig::default(),
            watch_interval_ms: 200,
        }
    }
}

struct Job {
    tokens: Vec<u32>,
    seq: u64,
    /// Explicit RNG stream ([`InferenceService::submit_with_seed`]);
    /// `None` derives from `seq` as before.
    seed: Option<u64>,
    enqueued: Instant,
    reply: mpsc::Sender<InferResult>,
}

struct Queue {
    jobs: VecDeque<Job>,
    open: bool,
}

struct Shared {
    backend: Arc<dyn QueryBackend>,
    cfg: ServeConfig,
    queue: Mutex<Queue>,
    not_empty: Condvar,
    not_full: Condvar,
    served: AtomicU64,
    batches: AtomicU64,
    peak_queue: AtomicU64,
}

/// Service counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    /// Queries answered.
    pub served: u64,
    /// Micro-batches drained (served / batches = realized batch size).
    pub batches: u64,
    /// Deepest queue observed.
    pub peak_queue: u64,
}

/// Handle to the worker pool. Dropping it shuts the pool down.
pub struct InferenceService {
    shared: Arc<Shared>,
    seq: AtomicU64,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl InferenceService {
    /// Spawn the pool over any hot-reloadable query backend — a single
    /// [`ServingHandle`](super::handle::ServingHandle) or a multi-replica
    /// [`ReplicaSet`](super::router::ReplicaSet); `Arc<ServingHandle>` /
    /// `Arc<ReplicaSet>` coerce at the call site.
    pub fn spawn(backend: Arc<dyn QueryBackend>, cfg: ServeConfig) -> InferenceService {
        let shared = Arc::new(Shared {
            backend,
            cfg: cfg.clone(),
            queue: Mutex::new(Queue {
                jobs: VecDeque::new(),
                open: true,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            peak_queue: AtomicU64::new(0),
        });
        let workers = (0..cfg.workers.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("serve-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn serve worker")
            })
            .collect();
        InferenceService {
            shared,
            seq: AtomicU64::new(0),
            workers,
        }
    }

    /// The backend whose current generation is being served.
    pub fn backend(&self) -> &Arc<dyn QueryBackend> {
        &self.shared.backend
    }

    /// Enqueue a query; blocks while the queue is at capacity
    /// (back-pressure). The receiver yields the result, or disconnects if
    /// the service shut down before the job ran.
    pub fn submit(&self, tokens: Vec<u32>) -> mpsc::Receiver<InferResult> {
        self.enqueue(tokens, None)
    }

    /// Enqueue a query with an explicit RNG stream: the worker derives
    /// `Rng::new(cfg.seed).derive(seed)` instead of using the request's
    /// sequence number. This is what makes answers over the wire
    /// bit-identical to in-process answers — the client names the stream,
    /// so the result no longer depends on arrival order.
    pub fn submit_with_seed(&self, tokens: Vec<u32>, seed: u64) -> mpsc::Receiver<InferResult> {
        self.enqueue(tokens, Some(seed))
    }

    fn enqueue(&self, tokens: Vec<u32>, seed: Option<u64>) -> mpsc::Receiver<InferResult> {
        let (reply, rx) = mpsc::channel();
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut q = self.shared.queue.lock().unwrap();
        while q.open && q.jobs.len() >= self.shared.cfg.queue_capacity.max(1) {
            q = self.shared.not_full.wait(q).unwrap();
        }
        if q.open {
            q.jobs.push_back(Job {
                tokens,
                seq,
                seed,
                enqueued: Instant::now(),
                reply,
            });
            self.shared
                .peak_queue
                .fetch_max(q.jobs.len() as u64, Ordering::Relaxed);
            self.shared.not_empty.notify_one();
        }
        // A closed queue drops `reply` here, surfacing as a recv error.
        rx
    }

    /// Blocking query: submit + wait. `None` if the service shut down.
    pub fn infer(&self, tokens: Vec<u32>) -> Option<InferResult> {
        self.submit(tokens).recv().ok()
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            served: self.shared.served.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
            peak_queue: self.shared.peak_queue.load(Ordering::Relaxed),
        }
    }

    fn close(shared: &Shared) {
        shared.queue.lock().unwrap().open = false;
        shared.not_empty.notify_all();
        shared.not_full.notify_all();
    }

    /// Drain outstanding work and stop the workers.
    pub fn shutdown(mut self) {
        Self::close(&self.shared);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        if !self.workers.is_empty() {
            Self::close(&self.shared);
            for h in self.workers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Synthesize a query stream: Zipf(1.07)-distributed words over `vocab`
/// with Poisson(`mean_len`) document lengths — the load generator shared
/// by `hplvm serve` and the serving benches.
pub fn synth_queries(vocab: usize, n: usize, mean_len: f64, seed: u64) -> Vec<Vec<u32>> {
    let zipf = Zipf::new(vocab.max(1), 1.07);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = rng.poisson(mean_len).max(1);
            (0..len).map(|_| zipf.sample(&mut rng) as u32).collect()
        })
        .collect()
}

/// Drive `queries` through the service keeping at most `window` requests
/// in flight from the caller's side; returns each answered query's
/// latency in seconds (queue wait + service time).
pub fn run_queries(
    svc: &InferenceService,
    queries: &[Vec<u32>],
    window: usize,
) -> Vec<f64> {
    let mut pending = VecDeque::new();
    let mut latencies = Vec::with_capacity(queries.len());
    let mut drain_one = |pending: &mut VecDeque<mpsc::Receiver<InferResult>>,
                         latencies: &mut Vec<f64>| {
        if let Some(rx) = pending.pop_front() {
            if let Ok(res) = rx.recv() {
                latencies.push(res.latency.as_secs_f64());
            }
        }
    };
    for doc in queries {
        pending.push_back(svc.submit(doc.clone()));
        while pending.len() > window.max(1) {
            drain_one(&mut pending, &mut latencies);
        }
    }
    while !pending.is_empty() {
        drain_one(&mut pending, &mut latencies);
    }
    latencies
}

fn worker_loop(shared: &Shared) {
    loop {
        let batch: Vec<Job> = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if !q.jobs.is_empty() {
                    break;
                }
                if !q.open {
                    return;
                }
                q = shared.not_empty.wait(q).unwrap();
            }
            let n = q.jobs.len().min(shared.cfg.max_batch.max(1));
            let batch = q.jobs.drain(..n).collect();
            shared.not_full.notify_all();
            batch
        };
        // Pin one generation for the whole batch: a concurrent reload
        // (single handle or set-wide replica commit) swaps the backend,
        // never this batch's pinned state.
        let pinned = shared.backend.pin();
        for job in batch {
            let stream = job.seed.unwrap_or(job.seq);
            let mut rng = Rng::new(shared.cfg.seed).derive(stream);
            let mut res = pinned.infer(&job.tokens, &shared.cfg.infer, &mut rng);
            res.latency = job.enqueued.elapsed();
            res.latency_micros = res.latency.as_micros() as u64;
            shared.served.fetch_add(1, Ordering::Relaxed);
            // The submitter may have stopped listening; that's fine.
            let _ = job.reply.send(res);
        }
        shared.batches.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::snapshot::{SnapshotMeta, Store};
    use crate::serve::handle::ServingHandle;
    use crate::serve::model::ServingModel;
    use crate::serve::router::ReplicaSet;

    fn toy_serving_model(weight: i32) -> ServingModel {
        let mut store = Store::new();
        for w in 0..10u32 {
            let row = if w < 5 { vec![weight, 0] } else { vec![0, weight] };
            store.insert((0, w), row.into());
        }
        let meta = SnapshotMeta {
            model: "AliasLDA".to_string(),
            k: 2,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 10,
            slot: 0,
            n_servers: 1,
            vnodes: 8,
            iterations: 1,
            run_id: 0,
            tables: None,
        };
        ServingModel::from_stores(meta, vec![store], 1 << 20).unwrap()
    }

    fn toy_model() -> Arc<ServingHandle> {
        ServingHandle::from_model(toy_serving_model(80))
    }

    #[test]
    fn serves_queries_from_many_threads() {
        let svc = Arc::new(InferenceService::spawn(toy_model(), ServeConfig::default()));
        let mut handles = Vec::new();
        for th in 0..4u32 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    let doc = if th % 2 == 0 {
                        vec![0u32, 1, 2, 3]
                    } else {
                        vec![6u32, 7, 8, 9]
                    };
                    let res = svc.infer(doc).expect("service dropped a query");
                    let want = if th % 2 == 0 { 0 } else { 1 };
                    assert_eq!(res.top_topics(1)[0].0, want);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = svc.stats();
        assert_eq!(stats.served, 100);
        assert!(stats.batches >= 1);
    }

    #[test]
    fn results_do_not_depend_on_pool_shape() {
        // Same seed, different worker/batch shapes → identical answers,
        // because each request's RNG stream derives from its sequence
        // number alone.
        let docs: Vec<Vec<u32>> = (0..12)
            .map(|i| (0..6).map(|j| ((i + j) % 10) as u32).collect())
            .collect();
        let run = |workers: usize, max_batch: usize| -> Vec<Vec<f64>> {
            let svc = InferenceService::spawn(
                toy_model(),
                ServeConfig {
                    workers,
                    max_batch,
                    ..Default::default()
                },
            );
            let rxs: Vec<_> = docs.iter().map(|d| svc.submit(d.clone())).collect();
            let out = rxs.into_iter().map(|rx| rx.recv().unwrap().theta).collect();
            svc.shutdown();
            out
        };
        assert_eq!(run(1, 1), run(4, 8));
    }

    #[test]
    fn explicit_seed_pins_the_answer_regardless_of_arrival_order() {
        // submit_with_seed names the RNG stream, so the same (doc, seed)
        // answers identically whatever else is in flight and in whatever
        // order requests arrive — the property the wire front-end's
        // parity tests lean on.
        let docs: Vec<Vec<u32>> = (0..10)
            .map(|i| (0..5).map(|j| ((i * 3 + j) % 10) as u32).collect())
            .collect();
        let run = |order: Vec<usize>| -> Vec<Vec<f64>> {
            let svc = InferenceService::spawn(
                toy_model(),
                ServeConfig {
                    workers: 3,
                    max_batch: 4,
                    ..Default::default()
                },
            );
            // Interleave unrelated traffic to shift sequence numbers.
            let noise: Vec<_> = (0..7).map(|_| svc.submit(vec![1u32, 2])).collect();
            let mut rxs: Vec<(usize, mpsc::Receiver<InferResult>)> = order
                .iter()
                .map(|&i| (i, svc.submit_with_seed(docs[i].clone(), 1000 + i as u64)))
                .collect();
            rxs.sort_by_key(|&(i, _)| i);
            let out = rxs
                .into_iter()
                .map(|(_, rx)| rx.recv().unwrap().theta)
                .collect();
            for rx in noise {
                rx.recv().unwrap();
            }
            svc.shutdown();
            out
        };
        let forward: Vec<usize> = (0..10).collect();
        let backward: Vec<usize> = (0..10).rev().collect();
        assert_eq!(run(forward), run(backward));
    }

    #[test]
    fn latency_micros_matches_the_duration_stamp() {
        let svc = InferenceService::spawn(toy_model(), ServeConfig::default());
        let res = svc.infer(vec![0u32, 1, 2, 3]).expect("served");
        assert_eq!(res.latency_micros, res.latency.as_micros() as u64);
        svc.shutdown();
    }

    #[test]
    fn micro_batching_actually_batches() {
        // One slow-start worker + a burst of queries → fewer batches than
        // queries.
        let svc = InferenceService::spawn(
            toy_model(),
            ServeConfig {
                workers: 1,
                max_batch: 64,
                ..Default::default()
            },
        );
        // Pin the single worker on a long document so the burst of small
        // queries accumulates in the queue behind it.
        let long_doc: Vec<u32> = (0..20_000).map(|i| (i % 10) as u32).collect();
        let pin = svc.submit(long_doc);
        let rxs: Vec<_> = (0..64).map(|_| svc.submit(vec![0u32, 1, 2])).collect();
        pin.recv().unwrap();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = svc.stats();
        assert_eq!(stats.served, 65);
        assert!(
            stats.batches < 64,
            "64 queries took {} batches — batching never engaged",
            stats.batches
        );
        svc.shutdown();
    }

    #[test]
    fn reload_mid_stream_answers_every_queued_request() {
        // One worker pinned on a long document, a pile of queries queued
        // behind it, a generation swap in the middle: nothing drops, and
        // a request submitted after the swap reports the new generation.
        let handle = toy_model();
        let svc = InferenceService::spawn(
            handle.clone(),
            ServeConfig {
                workers: 1,
                max_batch: 4,
                ..Default::default()
            },
        );
        let long_doc: Vec<u32> = (0..20_000).map(|i| (i % 10) as u32).collect();
        let pin = svc.submit(long_doc);
        let queued: Vec<_> = (0..32).map(|_| svc.submit(vec![0u32, 1, 2])).collect();
        let new_gen = handle.install(toy_serving_model(120)).expect("same family");
        assert_eq!(new_gen, 2);
        // Submitted strictly after the swap → must be served by gen 2.
        let after = svc.submit(vec![6u32, 7, 8]);
        let pinned = pin.recv().expect("pinned request dropped");
        // Whichever generation the first batch pinned, it answered.
        assert!(pinned.generation == 1 || pinned.generation == 2);
        for rx in queued {
            let res = rx.recv().expect("queued request dropped across reload");
            assert!((res.theta.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(
                res.generation == 1 || res.generation == 2,
                "unknown generation {}",
                res.generation
            );
        }
        let res = after.recv().expect("post-swap request dropped");
        assert_eq!(res.generation, 2);
        assert_eq!(svc.stats().served, 34);
        svc.shutdown();
    }

    #[test]
    fn replicated_backend_answers_like_the_single_handle() {
        // The same pool over a 2-replica set: every request's θ is
        // bit-identical to the single-handle service's (same per-request
        // RNG stream, bit-identical slice proposals).
        let mut store = Store::new();
        for w in 0..10u32 {
            store.insert((0, w), if w < 5 { vec![80, 0] } else { vec![0, 80] }.into());
        }
        let meta = SnapshotMeta {
            model: "AliasLDA".to_string(),
            k: 2,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 10,
            slot: 0,
            n_servers: 1,
            vnodes: 8,
            iterations: 1,
            run_id: 0,
            tables: None,
        };
        let set =
            ReplicaSet::from_stores(meta, vec![store], 2, 1 << 20).expect("replica set");
        let docs: Vec<Vec<u32>> = (0..8)
            .map(|i| (0..6).map(|j| ((i + j) % 10) as u32).collect())
            .collect();
        let run = |backend: Arc<dyn QueryBackend>| -> Vec<Vec<f64>> {
            let svc = InferenceService::spawn(backend, ServeConfig::default());
            let rxs: Vec<_> = docs.iter().map(|d| svc.submit(d.clone())).collect();
            let out = rxs.into_iter().map(|rx| rx.recv().unwrap().theta).collect();
            svc.shutdown();
            out
        };
        assert_eq!(run(toy_model()), run(set));
    }

    #[test]
    fn shutdown_disconnects_pending_cleanly() {
        let svc = InferenceService::spawn(toy_model(), ServeConfig::default());
        let rx = svc.submit(vec![0u32]);
        // Whether the job ran before shutdown or not, recv must not hang.
        svc.shutdown();
        let _ = rx.try_recv();
    }
}
