//! Budgeted LRU cache of per-word Walker alias tables.
//!
//! At serving time the word–topic statistics are frozen, so a word's
//! dense proposal `q_w(t) ∝ φ(w,t)` never goes stale — each table is
//! built **once** (O(K)) and then amortizes over every query that touches
//! the word, exactly the regime §3.1 engineers for training. A full table
//! set costs `O(V·K)` memory though (the reason the paper shards the
//! model in the first place), so tables are built lazily on first use and
//! evicted least-recently-used under a byte budget: the hot head of the
//! Zipf-distributed query vocabulary stays resident, the long tail is
//! rebuilt on demand.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::sampler::alias::AliasTable;

/// A word's frozen dense proposal: the alias table over the
/// prior-weighted weights `q_w(t) = prior_t·φ(w,t)`, plus the raw φ row
/// the sparse document-side component and the Metropolis-Hastings ratio
/// evaluate. (For LDA the prior is the constant α, so the table encodes
/// plain φ up to normalization; for HDP the root-stick prior reweights
/// it.)
pub struct WordProposal {
    /// O(1)-draw alias table over topics, built from `prior_t·φ(w,t)`.
    pub table: AliasTable,
    /// The frozen predictive row: `phi[t] = φ(w,t)`.
    pub phi: Box<[f64]>,
    /// `Σ_t prior_t·φ(w,t)` — the dense component's total mass.
    pub qsum: f64,
}

struct Entry {
    proposal: Arc<WordProposal>,
    last_used: u64,
}

struct Shard {
    entries: HashMap<u32, Entry>,
    /// Monotonic per-shard access clock (drives LRU ordering).
    tick: u64,
}

/// Cache statistics snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Lookups served from a resident table.
    pub hits: u64,
    /// Lookups that had to build a table.
    pub misses: u64,
    /// Tables evicted under the byte budget.
    pub evictions: u64,
    /// Tables built eagerly by a generation pre-warm (never counted as
    /// misses — post-swap miss counters isolate genuinely cold words).
    pub prewarmed: u64,
    /// Tables currently resident.
    pub resident: usize,
    /// Approximate resident bytes.
    pub resident_bytes: usize,
}

/// Sharded, budgeted LRU over [`WordProposal`]s.
pub struct AliasCache {
    shards: Vec<Mutex<Shard>>,
    /// Byte budget per shard (total budget split evenly).
    budget_per_shard: usize,
    /// Approximate bytes one cached table occupies.
    entry_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    prewarmed: AtomicU64,
}

impl AliasCache {
    /// A cache for `K`-topic tables under `budget_bytes` total, split
    /// over `n_shards` independently-locked shards (words hash to shards,
    /// so concurrent workers rarely contend).
    pub fn new(k: usize, budget_bytes: usize, n_shards: usize) -> AliasCache {
        let n_shards = n_shards.max(1);
        // prob (f64) + alias (u32) inside the table, phi (f64), plus
        // allocator/housekeeping slack.
        let entry_bytes = 96 + k * (8 + 4 + 8);
        // Every shard must be able to hold at least one table, whatever
        // the budget says — a zero-capacity cache would livelock builds.
        let budget_per_shard = (budget_bytes / n_shards).max(entry_bytes);
        AliasCache {
            shards: (0..n_shards)
                .map(|_| {
                    Mutex::new(Shard {
                        entries: HashMap::new(),
                        tick: 0,
                    })
                })
                .collect(),
            budget_per_shard,
            entry_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            prewarmed: AtomicU64::new(0),
        }
    }

    /// Fetch the proposal for `word`, building it with `build` on a miss.
    /// The O(K) build runs *outside* the shard lock so a miss on one word
    /// never stalls lookups of the other words in its shard; two threads
    /// racing on the same cold word may build twice, and the loser's
    /// table is discarded (the winner's is returned to both).
    pub fn get_or_build(
        &self,
        word: u32,
        build: impl FnOnce() -> WordProposal,
    ) -> Arc<WordProposal> {
        let shard = &self.shards[word as usize % self.shards.len()];
        {
            let mut s = shard.lock().unwrap();
            s.tick += 1;
            let tick = s.tick;
            if let Some(e) = s.entries.get_mut(&word) {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return e.proposal.clone();
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.insert(word, Arc::new(build())).0
    }

    /// Build `word`'s table eagerly if absent — the generation pre-warm
    /// path ([`super::model::ServingModel::prewarm_from`]). Counts into
    /// `prewarmed` rather than hits/misses, so post-swap miss counters
    /// isolate genuinely cold words; if a racing [`Self::get_or_build`]
    /// lands the table first, that build already counted as the miss and
    /// this pre-warm counts nothing. Respects the byte budget (an
    /// over-long pre-warm list evicts its own coldest entries). Returns
    /// `true` if this call's table became resident, `false` if one
    /// already was.
    pub fn prewarm(&self, word: u32, build: impl FnOnce() -> WordProposal) -> bool {
        let shard = &self.shards[word as usize % self.shards.len()];
        if shard.lock().unwrap().entries.contains_key(&word) {
            return false;
        }
        let (_, fresh) = self.insert(word, Arc::new(build()));
        if fresh {
            self.prewarmed.fetch_add(1, Ordering::Relaxed);
        }
        fresh
    }

    /// Insert a freshly-built proposal (or adopt the resident one if a
    /// racing build won — the `bool` says which), then enforce the byte
    /// budget by evicting the least-recently-used tables — never the
    /// entry just touched. Outstanding `Arc`s keep evicted tables alive
    /// for in-flight queries; the cache just forgets them.
    fn insert(&self, word: u32, proposal: Arc<WordProposal>) -> (Arc<WordProposal>, bool) {
        let shard = &self.shards[word as usize % self.shards.len()];
        let mut s = shard.lock().unwrap();
        s.tick += 1;
        let tick = s.tick;
        let mut fresh = false;
        let resident = s.entries.entry(word).or_insert_with(|| {
            fresh = true;
            Entry {
                proposal,
                last_used: tick,
            }
        });
        resident.last_used = tick;
        let result = resident.proposal.clone();
        let max_entries = (self.budget_per_shard / self.entry_bytes).max(1);
        if s.entries.len() > max_entries {
            let mut order: Vec<(u64, u32)> = s
                .entries
                .iter()
                .filter(|&(&w, _)| w != word)
                .map(|(&w, e)| (e.last_used, w))
                .collect();
            order.sort_unstable();
            let excess = s.entries.len() - max_entries;
            for &(_, w) in order.iter().take(excess) {
                s.entries.remove(&w);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        (result, fresh)
    }

    /// Words with resident tables, coldest-first by per-shard LRU tick
    /// (cross-shard order is approximate — ticks are per-shard clocks).
    /// Feeding this list into a pre-warm in order makes the hottest words
    /// the last inserted, i.e. the survivors if the receiving cache's
    /// budget is tighter than the resident set.
    pub fn resident_words(&self) -> Vec<u32> {
        let mut order: Vec<(u64, u32)> = Vec::new();
        for shard in &self.shards {
            let s = shard.lock().unwrap();
            order.extend(s.entries.iter().map(|(&w, e)| (e.last_used, w)));
        }
        order.sort_unstable();
        order.into_iter().map(|(_, w)| w).collect()
    }

    /// Current statistics.
    pub fn stats(&self) -> CacheStats {
        let mut resident = 0usize;
        for shard in &self.shards {
            resident += shard.lock().unwrap().entries.len();
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            prewarmed: self.prewarmed.load(Ordering::Relaxed),
            resident,
            resident_bytes: resident * self.entry_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn proposal(k: usize, seed: f64) -> WordProposal {
        let phi: Vec<f64> = (0..k).map(|t| seed + t as f64).collect();
        let qsum = phi.iter().sum();
        WordProposal {
            table: AliasTable::build(&phi),
            phi: phi.into_boxed_slice(),
            qsum,
        }
    }

    #[test]
    fn hit_after_build() {
        let c = AliasCache::new(8, 1 << 20, 4);
        let p1 = c.get_or_build(3, || proposal(8, 1.0));
        let p2 = c.get_or_build(3, || panic!("must not rebuild a resident word"));
        assert!(Arc::ptr_eq(&p1, &p2));
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
    }

    #[test]
    fn budget_evicts_lru_not_hot() {
        // Budget for ~2 tables in a single shard.
        let k = 8;
        let entry = 96 + k * 20;
        let c = AliasCache::new(k, entry * 2, 1);
        c.get_or_build(0, || proposal(k, 0.0));
        c.get_or_build(1, || proposal(k, 1.0));
        // Touch word 0 so word 1 is the LRU victim.
        c.get_or_build(0, || panic!("0 must be resident"));
        c.get_or_build(2, || proposal(k, 2.0));
        let st = c.stats();
        assert!(st.evictions >= 1, "budget never enforced");
        assert!(st.resident <= 2);
        // Word 0 survived; word 1 was evicted and rebuilds.
        c.get_or_build(0, || panic!("hot word evicted"));
        let misses_before = c.stats().misses;
        c.get_or_build(1, || proposal(k, 1.0));
        assert_eq!(c.stats().misses, misses_before + 1);
    }

    #[test]
    fn evicted_tables_survive_via_arc() {
        let k = 4;
        let entry = 96 + k * 20;
        let c = AliasCache::new(k, entry, 1); // room for exactly one
        let held = c.get_or_build(7, || proposal(k, 7.0));
        c.get_or_build(8, || proposal(k, 8.0)); // evicts 7
        assert_eq!(held.phi[0], 7.0, "in-flight Arc invalidated by eviction");
    }

    #[test]
    fn prewarm_builds_once_and_never_counts_as_miss() {
        let c = AliasCache::new(8, 1 << 20, 4);
        assert!(c.prewarm(5, || proposal(8, 5.0)));
        assert!(!c.prewarm(5, || panic!("resident word must not rebuild")));
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.prewarmed), (0, 0, 1));
        // The first real lookup of a pre-warmed word is a hit, not a build.
        c.get_or_build(5, || panic!("pre-warmed word must not rebuild"));
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (1, 0));
    }

    #[test]
    fn resident_words_orders_cold_to_hot() {
        let c = AliasCache::new(4, 1 << 20, 1); // one shard → exact LRU order
        for w in [3u32, 1, 4] {
            c.get_or_build(w, || proposal(4, w as f64));
        }
        c.get_or_build(3, || panic!("resident")); // 3 becomes hottest
        assert_eq!(c.resident_words(), vec![1, 4, 3]);
    }

    #[test]
    fn tiny_budget_still_serves() {
        let c = AliasCache::new(64, 0, 4); // degenerate budget
        for w in 0..100u32 {
            let p = c.get_or_build(w, || proposal(64, w as f64));
            assert_eq!(p.phi.len(), 64);
        }
        assert!(c.stats().resident >= 1);
    }
}
