//! Fold-in inference: estimate a held-out document's topic mixture
//! against the frozen serving model — for *any* serving family.
//!
//! Under frozen statistics every family's document-side collapsed
//! conditional takes the same two-term shape (eq. (4) with the word–topic
//! side constant):
//!
//! ```text
//! p(z=t | rest) ∝ n_td·φ(w,t)       — sparse, k_d terms, exact
//!              + prior_t·φ(w,t)     — dense, served by the word's alias table
//! ```
//!
//! where `φ` and `prior_t` come from the snapshot's
//! [`ServingFamily`](super::family::ServingFamily): Dirichlet φ with flat
//! α for LDA, the Pitman-Yor predictive for PDP, and the root-stick
//! weighted prior `b₁·θ₀(t)` for HDP. The alias table is built over the
//! prior-weighted weights, so the two-branch mixture proposal *is* the
//! target — the regime where the Metropolis-Hastings-Walker machinery
//! amortizes perfectly: tables are built once per word (never stale), the
//! sparse term costs `O(k_d)`, and the MH acceptance ratio is identically
//! 1 for every family. A short chain per token over a handful of sweeps
//! yields the Rao-Blackwellized mixture estimate
//! `θ̂_t = (n̄_td + prior_t) / (N_d + Σ_t prior_t)`.

use std::sync::Arc;
use std::time::Duration;

use super::cache::WordProposal;
use super::model::ServingModel;
use crate::sampler::doc_state::SparseCounts;
use crate::sampler::mh::mh_chain;
use crate::util::rng::Rng;

/// Fold-in chain configuration.
#[derive(Clone, Copy, Debug)]
pub struct InferConfig {
    /// Sweeps discarded before mixture accumulation.
    pub burnin: usize,
    /// Sweeps averaged into the mixture estimate.
    pub samples: usize,
    /// MH steps per token (parity with training; acceptance is ≈1 here).
    pub mh_steps: usize,
}

impl Default for InferConfig {
    fn default() -> Self {
        InferConfig {
            burnin: 4,
            samples: 2,
            mh_steps: 2,
        }
    }
}

/// One query's outcome.
#[derive(Clone, Debug)]
pub struct InferResult {
    /// Topic mixture θ̂ (sums to 1).
    pub theta: Vec<f64>,
    /// Tokens folded in.
    pub tokens: usize,
    /// MH proposals made (diagnostics).
    pub proposed: u64,
    /// MH proposals accepted (≈ proposed: the frozen proposal is exact).
    pub accepted: u64,
    /// Snapshot generation that answered the query — filled by the
    /// serving layer ([`super::service`]) from the
    /// [`ServingHandle`](super::handle::ServingHandle); 0 for direct
    /// calls outside a handle.
    pub generation: u64,
    /// Replica ids that contributed word proposals, ascending — filled
    /// by the routed path ([`super::router::SetGeneration::infer_doc`]);
    /// empty when a single unrouted model served the query.
    pub served_by: Vec<u32>,
    /// Queue + service latency; filled by the serving layer
    /// ([`super::service`]), zero for direct calls.
    pub latency: Duration,
    /// `latency` in integer microseconds, stamped by the service worker —
    /// the one measurement the wire protocol, the loadgen client, and the
    /// in-process bench all report, so their numbers are comparable.
    pub latency_micros: u64,
}

impl InferResult {
    /// Topics sorted by descending mixture weight.
    pub fn top_topics(&self, n: usize) -> Vec<(usize, f64)> {
        let mut order: Vec<(usize, f64)> = self.theta.iter().copied().enumerate().collect();
        order.sort_by(|a, b| b.1.total_cmp(&a.1));
        order.truncate(n);
        order
    }
}

/// Fold one document into the frozen model. Deterministic given `rng`.
pub fn infer_doc(
    model: &ServingModel,
    tokens: &[u32],
    cfg: &InferConfig,
    rng: &mut Rng,
) -> InferResult {
    // Resolve every token's proposal once per query. The `Arc`s pin the
    // tables for the query's whole lifetime, so this costs one cache
    // round-trip per token instead of one per token per sweep — and a
    // mid-query eviction can never force a rebuild inside the sweeps.
    let proposals: Vec<Arc<WordProposal>> =
        tokens.iter().map(|&w| model.proposal(w)).collect();
    infer_with_proposals(
        model.k(),
        model.priors(),
        model.prior_total(),
        &proposals,
        cfg,
        rng,
    )
}

/// The fold-in core over already-resolved per-token proposals — shared by
/// the single-model path ([`infer_doc`]) and the routed multi-replica
/// path ([`super::router::SetGeneration::infer_doc`]), which gathers each
/// word's proposal from its owning replica first. Because a replica
/// slice's proposals are bit-identical to the full model's and this core
/// consumes `rng` identically in both cases, the routed posterior equals
/// the single-replica posterior bit-for-bit under a fixed seed.
pub fn infer_with_proposals(
    k: usize,
    priors: &[f64],
    prior_total: f64,
    proposals: &[Arc<WordProposal>],
    cfg: &InferConfig,
    rng: &mut Rng,
) -> InferResult {
    if proposals.is_empty() || k == 0 {
        // No evidence: the mixture is the normalized family prior.
        let theta = if prior_total > 0.0 {
            priors.iter().map(|&p| p / prior_total).collect()
        } else {
            vec![1.0 / k.max(1) as f64; k]
        };
        return InferResult {
            theta,
            tokens: 0,
            proposed: 0,
            accepted: 0,
            generation: 0,
            served_by: Vec::new(),
            latency: Duration::ZERO,
            latency_micros: 0,
        };
    }

    // Init: draw each token from its word's prior-weighted frozen
    // proposal — a far better starting point than uniform for peaked φ.
    let mut n_dt = SparseCounts::new();
    let mut z: Vec<u32> = Vec::with_capacity(proposals.len());
    for prop in proposals {
        let t = prop.table.sample(rng) as u32;
        n_dt.inc(t);
        z.push(t);
    }

    let samples = cfg.samples.max(1);
    let sweeps = cfg.burnin + samples;
    let mut acc = vec![0u64; k];
    let mut proposed = 0u64;
    let mut accepted = 0u64;
    let mut sparse_topics: Vec<u32> = Vec::with_capacity(16);
    let mut sparse_weights: Vec<f64> = Vec::with_capacity(16);

    for sweep in 0..sweeps {
        for i in 0..proposals.len() {
            let old = z[i];
            n_dt.dec(old);
            let prop = &proposals[i];

            // Sparse document component: n_td·φ(w,t) over the non-zero
            // topics of this document.
            sparse_topics.clear();
            sparse_weights.clear();
            let mut sparse_sum = 0.0;
            for (t, c) in n_dt.iter() {
                let wgt = c as f64 * prop.phi[t as usize];
                sparse_topics.push(t);
                sparse_weights.push(wgt);
                sparse_sum += wgt;
            }
            let dense_sum = prop.qsum;
            let total = sparse_sum + dense_sum;

            // One mass function serves as both proposal and target —
            // q(t) = p(t) ∝ (n_td + prior_t)·φ(w,t) — which is what makes
            // the MH acceptance identically 1 under frozen φ, for every
            // family. Passing the same (Copy) closure twice keeps that
            // invariant structural.
            let counts = &n_dt;
            let phi = &prop.phi;
            let pq_of =
                |t: usize| (counts.get(t as u32) as f64 + priors[t]) * phi[t];
            let topics = &sparse_topics;
            let weights = &sparse_weights;
            let table = &prop.table;
            let propose = |r: &mut Rng| {
                if total > 0.0 && r.f64() * total < sparse_sum {
                    // O(k_d) exact categorical over the sparse component.
                    let mut u = r.f64() * sparse_sum;
                    let mut idx = topics.len().saturating_sub(1);
                    for (j, &wgt) in weights.iter().enumerate() {
                        u -= wgt;
                        if u <= 0.0 {
                            idx = j;
                            break;
                        }
                    }
                    let t = topics.get(idx).copied().unwrap_or(0) as usize;
                    (t, pq_of(t))
                } else {
                    // O(1) alias draw from the prior-weighted dense
                    // component.
                    let t = table.sample(r);
                    (t, pq_of(t))
                }
            };

            let (new_t, acc_n) =
                mh_chain(Some(old as usize), cfg.mh_steps, propose, pq_of, pq_of, rng);
            proposed += cfg.mh_steps.max(1) as u64;
            accepted += acc_n as u64;

            let new_t = new_t as u32;
            z[i] = new_t;
            n_dt.inc(new_t);
        }
        if sweep >= cfg.burnin {
            for (t, c) in n_dt.iter() {
                acc[t as usize] += c as u64;
            }
        }
    }

    // Rao-Blackwellized mixture: prior-smoothed average counts.
    let n_d = proposals.len() as f64;
    let denom = n_d + prior_total;
    let theta: Vec<f64> = acc
        .iter()
        .zip(priors.iter())
        .map(|(&a, &p)| (a as f64 / samples as f64 + p) / denom)
        .collect();
    InferResult {
        theta,
        tokens: proposals.len(),
        proposed,
        accepted,
        generation: 0,
        served_by: Vec::new(),
        latency: Duration::ZERO,
        latency_micros: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::snapshot::{SnapshotMeta, Store, TableHyper};

    fn meta(model: &str, k: u32, tables: Option<TableHyper>) -> SnapshotMeta {
        SnapshotMeta {
            model: model.to_string(),
            k,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 10,
            slot: 0,
            n_servers: 1,
            vnodes: 8,
            iterations: 1,
            run_id: 0,
            tables,
        }
    }

    /// Two sharply-separated topics: words 0..5 → topic 0, 5..10 → topic 1.
    fn toy_model() -> ServingModel {
        let mut store = Store::new();
        for w in 0..10u32 {
            let row = if w < 5 { vec![100, 0] } else { vec![0, 100] };
            store.insert((0, w), row.into());
        }
        ServingModel::from_stores(meta("AliasLDA", 2, None), vec![store], 1 << 20).unwrap()
    }

    /// Same separation expressed as PDP statistics (customers + tables).
    fn toy_pdp_model() -> ServingModel {
        let mut store = Store::new();
        for w in 0..10u32 {
            let (m, s) = if w < 5 {
                (vec![100, 0], vec![8, 0])
            } else {
                (vec![0, 100], vec![0, 8])
            };
            store.insert((0, w), m.into());
            store.insert((1, w), s.into());
        }
        let meta = meta(
            "AliasPDP",
            2,
            Some(TableHyper {
                discount: 0.1,
                concentration: 10.0,
                root: 0.5,
            }),
        );
        ServingModel::from_stores(meta, vec![store], 1 << 20).unwrap()
    }

    /// HDP statistics: three truncation slots, the third unrepresented.
    fn toy_hdp_model() -> ServingModel {
        let mut store = Store::new();
        for w in 0..10u32 {
            let row = if w < 5 {
                vec![100, 0, 0]
            } else {
                vec![0, 100, 0]
            };
            store.insert((0, w), row.into());
        }
        store.insert((1, 0), vec![10, 10, 0].into());
        let meta = meta(
            "AliasHDP",
            3,
            Some(TableHyper {
                discount: 0.0,
                concentration: 1.0,
                root: 1.0,
            }),
        );
        ServingModel::from_stores(meta, vec![store], 1 << 20).unwrap()
    }

    #[test]
    fn pure_doc_concentrates_on_its_topic() {
        let m = toy_model();
        let mut rng = Rng::new(1);
        let res = infer_doc(&m, &[0, 1, 2, 3, 4, 0, 1, 2], &InferConfig::default(), &mut rng);
        assert_eq!(res.tokens, 8);
        assert!((res.theta.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(res.theta[0] > 0.9, "θ = {:?}", res.theta);
        assert_eq!(res.top_topics(1)[0].0, 0);
    }

    #[test]
    fn mixed_doc_splits_mass() {
        let m = toy_model();
        let mut rng = Rng::new(2);
        let res = infer_doc(&m, &[0, 1, 7, 8, 2, 9, 3, 6], &InferConfig::default(), &mut rng);
        assert!(res.theta[0] > 0.25 && res.theta[0] < 0.75, "θ = {:?}", res.theta);
    }

    #[test]
    fn pdp_doc_concentrates_on_its_topic() {
        let m = toy_pdp_model();
        let mut rng = Rng::new(11);
        let res = infer_doc(&m, &[5, 6, 7, 8, 9, 5, 6, 7], &InferConfig::default(), &mut rng);
        assert!((res.theta.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(res.theta[1] > 0.9, "PDP θ = {:?}", res.theta);
    }

    #[test]
    fn hdp_doc_concentrates_and_skips_unrepresented_topics() {
        let m = toy_hdp_model();
        let mut rng = Rng::new(12);
        let res = infer_doc(&m, &[0, 1, 2, 3, 4, 0, 1], &InferConfig::default(), &mut rng);
        assert!((res.theta.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(res.theta[0] > 0.85, "HDP θ = {:?}", res.theta);
        // The unrepresented truncation slot gets (essentially) nothing.
        assert!(res.theta[2] < 0.01, "HDP θ = {:?}", res.theta);
    }

    #[test]
    fn acceptance_is_near_one_for_frozen_proposals() {
        // The exact-proposal property must hold for every family.
        for (m, seed) in [
            (toy_model(), 3u64),
            (toy_pdp_model(), 13),
            (toy_hdp_model(), 14),
        ] {
            let mut rng = Rng::new(seed);
            let doc: Vec<u32> = (0..200).map(|i| (i % 10) as u32).collect();
            let res = infer_doc(&m, &doc, &InferConfig::default(), &mut rng);
            let rate = res.accepted as f64 / res.proposed as f64;
            assert!(rate > 0.999, "exact proposal must always accept ({rate})");
        }
    }

    #[test]
    fn empty_doc_returns_normalized_prior() {
        let m = toy_model();
        let mut rng = Rng::new(4);
        let res = infer_doc(&m, &[], &InferConfig::default(), &mut rng);
        assert_eq!(res.tokens, 0);
        // Flat LDA prior → uniform.
        assert_eq!(res.theta, vec![0.5, 0.5]);
        // HDP prior follows the root sticks instead.
        let h = toy_hdp_model();
        let res = infer_doc(&h, &[], &InferConfig::default(), &mut rng);
        assert!((res.theta.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(res.theta[0] > 0.45 && res.theta[1] > 0.45);
        assert!(res.theta[2] < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let m = toy_model();
        let doc = [0u32, 6, 1, 7, 2, 8];
        let a = infer_doc(&m, &doc, &InferConfig::default(), &mut Rng::new(9));
        let b = infer_doc(&m, &doc, &InferConfig::default(), &mut Rng::new(9));
        assert_eq!(a.theta, b.theta);
    }
}
