//! The family-generic serving abstraction.
//!
//! The paper's claim is that *one* parameter-server system spans LDA,
//! Pitman-Yor (PDP), and HDP (§2, §4): the families differ only in which
//! sufficient statistics they freeze and how those statistics turn into a
//! predictive word distribution. A [`ServingFamily`] captures exactly
//! that contract — "frozen sufficient statistics → `φ(w,t)` + a
//! document-side prior" — so the fold-in machinery
//! ([`super::infer::infer_doc`]), the alias cache, the micro-batching
//! service, and the hot-reload handle are written once and shared by all
//! three families.
//!
//! Under frozen statistics the fold-in conditional for every family
//! collapses to the same two-term shape as eq. (4):
//!
//! ```text
//! p(z=t | rest) ∝ (n_td + prior_t) · φ(w,t)
//! ```
//!
//! with family-specific ingredients:
//!
//! | family | `φ(w,t)`                                   | `prior_t`      |
//! |--------|--------------------------------------------|----------------|
//! | LDA    | `(n_tw+β)/(n_t+β̄)`                         | `α`            |
//! | PDP    | PYP predictive from `(m_tw, s_tw)` (eq. 5) | `α`            |
//! | HDP    | `(n_tw+β)/(n_t+β̄)`                         | `b₁·θ₀(t)`     |
//!
//! The φ implementations delegate to the training-side posterior terms
//! ([`crate::sampler::pdp::pyp_predictive`],
//! [`crate::sampler::hdp::root_stick`],
//! [`crate::sampler::hdp::dirichlet_predictive`]) so serving can never
//! drift from the math the samplers and the evaluation stack use.
//!
//! Families are built from a decoded snapshot directory by
//! [`family_from_stores`]: matrix 0 is always the primary word–topic
//! statistic; matrix 1 carries the table-side statistics (PDP `s_tw`
//! rows, the HDP root `t_k` row), and the v3 snapshot header's
//! [`TableHyper`] section supplies the hyperparameters that give those
//! counts meaning.

use crate::config::ModelKind;
use crate::ps::snapshot::{SnapshotMeta, Store, TableHyper};
use crate::sampler::counts::HybridRow;
use crate::sampler::hdp::{dirichlet_predictive, root_stick};
use crate::sampler::pdp::pyp_predictive;
use crate::Result;

/// Frozen per-family sufficient statistics + posterior terms.
///
/// Implementations are immutable after construction and shared across the
/// worker pool (`Send + Sync`). Everything the generic fold-in needs:
/// the predictive word distribution `φ(w,t)` and the document-side prior
/// mass `prior_t` (the dense-component weights of the MH-Walker mixture
/// proposal).
pub trait ServingFamily: Send + Sync {
    /// The model kind recorded by the producing training run.
    fn kind(&self) -> ModelKind;

    /// Topic count (HDP: the truncation `K_max`).
    fn k(&self) -> usize;

    /// Vocabulary size served.
    fn vocab(&self) -> usize;

    /// Frozen predictive word probability `p(w | z=t)`.
    fn phi(&self, w: u32, t: usize) -> f64;

    /// Document-side prior mass for topic `t` (`α`, or `b₁·θ₀(t)` for
    /// HDP — matching [`crate::eval::perplexity::TopicModelView`] so the
    /// served mixtures and the evaluation stack agree).
    fn doc_prior(&self, t: usize) -> f64;

    /// Total (clamped) token mass in the frozen primary statistic.
    fn total_tokens(&self) -> i64;

    /// Whether this family materializes per-word statistics for `w`.
    /// A vocabulary *slice* (multi-replica serving) answers `false` for
    /// words it does not own; the full model answers `false` only for
    /// words never observed in training.
    fn has_row(&self, w: u32) -> bool;
}

/// One shared matrix merged across the slot stores: the slots' key sets
/// are disjoint by consistent hashing, so the global statistic is the
/// row-wise (saturating) sum.
struct Merged {
    /// Hybrid rows: a 1M-vocab slice at K=10k holds O(nnz) per word, not
    /// a dense `i32[K]` ghost per touched word.
    rows: Vec<Option<HybridRow>>,
    /// Per-topic totals over clamped entries (eventual consistency can
    /// leave transient negatives in a snapshot; clamp at the aggregate
    /// like the samplers do).
    totals: Vec<i64>,
}

impl Merged {
    /// One scan of the stores producing `parts` [`Merged`] matrices:
    /// word `w`'s merged row is materialized only in part `owner(w)`,
    /// while every part carries the identical **global** per-topic totals
    /// over every word's cross-store sum, clamped per cell at the
    /// aggregate (totals are integer sums, hence order-independent, so a
    /// part normalizes bit-identically to the full merge). This is what
    /// lets a replica set build all N vocabulary slices from a *single*
    /// pass over the decoded stores instead of re-scanning once per
    /// replica; the full merge is just the 1-part partition.
    fn build_parts(
        stores: &[Store],
        matrix: u8,
        vocab: usize,
        k: usize,
        parts: usize,
        owner: &dyn Fn(u32) -> u32,
    ) -> Vec<Merged> {
        // Words of this matrix present in any store.
        let mut seen = vec![false; vocab];
        for store in stores {
            for &(m, word) in store.keys() {
                if m == matrix && (word as usize) < vocab {
                    seen[word as usize] = true;
                }
            }
        }
        let mut rows: Vec<Vec<Option<HybridRow>>> =
            (0..parts).map(|_| vec![None; vocab]).collect();
        let mut totals = vec![0i64; k];
        let mut scratch = vec![0i32; k];
        for w in 0..vocab as u32 {
            if !seen[w as usize] {
                continue;
            }
            scratch.iter_mut().for_each(|c| *c = 0);
            for store in stores {
                if let Some(row) = store.get(&(matrix, w)) {
                    row.for_each(|t, v| {
                        let t = t as usize;
                        if t < k {
                            scratch[t] = scratch[t].saturating_add(v);
                        }
                    });
                }
            }
            for (t, &v) in scratch.iter().enumerate() {
                totals[t] += v.max(0) as i64;
            }
            let part = (owner(w) as usize).min(parts - 1);
            rows[part][w as usize] = Some(HybridRow::from_dense(&scratch));
        }
        rows.into_iter()
            .map(|rows| Merged {
                rows,
                totals: totals.clone(),
            })
            .collect()
    }

    /// Whether `w` has a materialized row.
    #[inline]
    fn has_row(&self, w: u32) -> bool {
        self.rows
            .get(w as usize)
            .map_or(false, |r| r.is_some())
    }

    /// Clamped cell read (0 for never-observed words).
    #[inline]
    fn count(&self, w: u32, t: usize) -> i32 {
        match self.rows.get(w as usize).and_then(|r| r.as_ref()) {
            Some(row) => row.get(t).max(0),
            None => 0,
        }
    }

    #[inline]
    fn total(&self, t: usize) -> f64 {
        self.totals[t] as f64
    }

    fn grand_total(&self) -> i64 {
        self.totals.iter().sum()
    }
}

/// Largest word id + 1 observed in the given matrices.
fn max_word(stores: &[Store], matrices: &[u8]) -> usize {
    stores
        .iter()
        .flat_map(|s| s.keys())
        .filter(|(m, _)| matrices.contains(m))
        .map(|&(_, w)| w as usize + 1)
        .max()
        .unwrap_or(0)
}

/// LDA serving: frozen `n_tw` + symmetric Dirichlet priors. Serves both
/// LDA samplers (YahooLDA and AliasLDA share the statistic).
pub struct LdaFamily {
    kind: ModelKind,
    k: usize,
    vocab: usize,
    alpha: f64,
    beta: f64,
    beta_bar: f64,
    n: Merged,
}

impl ServingFamily for LdaFamily {
    fn kind(&self) -> ModelKind {
        self.kind
    }
    fn k(&self) -> usize {
        self.k
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn phi(&self, w: u32, t: usize) -> f64 {
        dirichlet_predictive(
            self.n.count(w, t) as f64,
            self.n.total(t).max(0.0),
            self.beta,
            self.beta_bar,
        )
    }
    fn doc_prior(&self, _t: usize) -> f64 {
        self.alpha
    }
    fn total_tokens(&self) -> i64 {
        self.n.grand_total()
    }
    fn has_row(&self, w: u32) -> bool {
        self.n.has_row(w)
    }
}

/// PDP serving: frozen customer counts `m_tw` (matrix 0) *and* table
/// counts `s_tw` (matrix 1), combined by the PYP predictive rule with the
/// v3 snapshot's `(a, b, γ)` hyperparameters.
pub struct PdpFamily {
    k: usize,
    vocab: usize,
    alpha: f64,
    discount: f64,
    concentration: f64,
    gamma: f64,
    gamma_bar: f64,
    m: Merged,
    s: Merged,
}

impl ServingFamily for PdpFamily {
    fn kind(&self) -> ModelKind {
        ModelKind::AliasPdp
    }
    fn k(&self) -> usize {
        self.k
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn phi(&self, w: u32, t: usize) -> f64 {
        pyp_predictive(
            self.m.count(w, t) as f64,
            self.s.count(w, t) as f64,
            self.m.total(t).max(0.0),
            self.s.total(t).max(0.0),
            self.discount,
            self.concentration,
            self.gamma,
            self.gamma_bar,
        )
    }
    fn doc_prior(&self, _t: usize) -> f64 {
        self.alpha
    }
    fn total_tokens(&self) -> i64 {
        self.m.grand_total()
    }
    fn has_row(&self, w: u32) -> bool {
        self.m.has_row(w) || self.s.has_row(w)
    }
}

/// HDP serving: frozen `n_tw` (matrix 0) plus the root table counts `t_k`
/// (matrix 1, row 0) that weight the document-side prior `b₁·θ₀(t)` —
/// topics the root restaurant never registered get (almost) no fold-in
/// mass, matching the HDP document model and the evaluation stack.
pub struct HdpFamily {
    k: usize,
    vocab: usize,
    b0: f64,
    b1: f64,
    beta: f64,
    beta_bar: f64,
    n: Merged,
    /// Clamped root table counts `t_k`.
    root: Vec<i64>,
    root_total: f64,
}

impl ServingFamily for HdpFamily {
    fn kind(&self) -> ModelKind {
        ModelKind::AliasHdp
    }
    fn k(&self) -> usize {
        self.k
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn phi(&self, w: u32, t: usize) -> f64 {
        dirichlet_predictive(
            self.n.count(w, t) as f64,
            self.n.total(t).max(0.0),
            self.beta,
            self.beta_bar,
        )
    }
    fn doc_prior(&self, t: usize) -> f64 {
        // The ε keeps unrepresented topics sample-able under transient
        // inconsistency, mirroring AliasHdp's TopicModelView.
        self.b1 * root_stick(self.root[t] as f64, self.root_total, self.b0, self.k) + 1e-9
    }
    fn total_tokens(&self) -> i64 {
        self.n.grand_total()
    }
    fn has_row(&self, w: u32) -> bool {
        self.n.has_row(w)
    }
}

/// Build the family a snapshot directory's statistics belong to.
///
/// Dispatches on the family the v2+ header records ([`ModelKind::parse`]
/// of `meta.model`); PDP/HDP additionally require the v3 [`TableHyper`]
/// section — a v2-era PDP/HDP snapshot has table *counts* but not the
/// hyperparameters to interpret them, so it is refused with a re-train
/// hint rather than served wrong.
pub fn family_from_stores(
    meta: &SnapshotMeta,
    stores: &[Store],
) -> Result<Box<dyn ServingFamily>> {
    family_from_stores_sliced(meta, stores, None)
}

/// [`family_from_stores`] with an optional vocabulary-slice filter
/// (multi-replica serving, [`crate::serve::router`]).
///
/// When `owned` is given, per-word rows are materialized only for the
/// words it accepts, while every *normalizer* stays global — per-topic
/// totals run over all stores' rows, the vocabulary size (hence `β̄`/`γ̄`)
/// comes from all matrices, and the HDP root table row (matrix 1, row 0
/// — prior state, not a vocabulary word) is never filtered. That is what
/// makes a slice's `φ(w,t)` for an owned word bit-identical to the
/// unsliced model's, which in turn is what makes routed inference
/// bit-identical to single-replica inference.
pub fn family_from_stores_sliced(
    meta: &SnapshotMeta,
    stores: &[Store],
    owned: Option<&dyn Fn(u32) -> bool>,
) -> Result<Box<dyn ServingFamily>> {
    // One implementation serves both shapes: a single build is the
    // 1-part partition, and a filtered slice is part 0 of a kept/dropped
    // 2-part partition (the dropped part is transient — this path only
    // builds one slice at a time; replica sets go through
    // [`families_from_stores_partitioned`] directly).
    let mut parts = match owned {
        None => families_from_stores_partitioned(meta, stores, 1, &|_| 0)?,
        Some(keep) => families_from_stores_partitioned(meta, stores, 2, &|w| {
            u32::from(!keep(w))
        })?,
    };
    Ok(parts.swap_remove(0))
}

/// Build **all** `parts` vocabulary-sliced families in a single scan of
/// the stores — the multi-replica load/reload path (N slices for the
/// cost of one scan instead of one scan per replica), and the engine
/// behind [`family_from_stores`] / [`family_from_stores_sliced`]. Part
/// `p` materializes per-word statistics only for words with
/// `owner(w) == p`; every normalizer stays global, so each part's
/// `φ(w,t)` for an owned word is bit-identical to the full model's.
pub fn families_from_stores_partitioned(
    meta: &SnapshotMeta,
    stores: &[Store],
    parts: usize,
    owner: &dyn Fn(u32) -> u32,
) -> Result<Vec<Box<dyn ServingFamily>>> {
    anyhow::ensure!(parts >= 1, "need at least one part");
    anyhow::ensure!(meta.k > 0, "snapshot metadata has K = 0");
    let kind = ModelKind::parse(&meta.model).ok_or_else(|| {
        anyhow::anyhow!(
            "snapshot records unknown model family {:?} — this build serves \
             LDA, PDP, and HDP",
            meta.model
        )
    })?;
    let k = meta.k as usize;
    let need_tables = || {
        meta.tables.ok_or_else(|| {
            anyhow::anyhow!(
                "{} snapshot predates format v3 and carries no table-side \
                 hyperparameters; re-train to serve it",
                meta.model
            )
        })
    };
    match kind {
        ModelKind::YahooLda | ModelKind::AliasLda => {
            let vocab = (meta.vocab_size as usize).max(max_word(stores, &[0]));
            anyhow::ensure!(vocab > 0, "snapshot contains no word rows");
            Ok(Merged::build_parts(stores, 0, vocab, k, parts, owner)
                .into_iter()
                .map(|n| {
                    Box::new(LdaFamily {
                        kind,
                        k,
                        vocab,
                        alpha: meta.alpha,
                        beta: meta.beta,
                        beta_bar: meta.beta * vocab as f64,
                        n,
                    }) as Box<dyn ServingFamily>
                })
                .collect())
        }
        ModelKind::AliasPdp => {
            let hyper: TableHyper = need_tables()?;
            let vocab = (meta.vocab_size as usize).max(max_word(stores, &[0, 1]));
            anyhow::ensure!(vocab > 0, "snapshot contains no word rows");
            // Table rows (s_tw) follow their word's slice, so a word's
            // customers and tables always live together.
            let ms = Merged::build_parts(stores, 0, vocab, k, parts, owner);
            let ss = Merged::build_parts(stores, 1, vocab, k, parts, owner);
            Ok(ms
                .into_iter()
                .zip(ss)
                .map(|(m, s)| {
                    Box::new(PdpFamily {
                        k,
                        vocab,
                        alpha: meta.alpha,
                        discount: hyper.discount,
                        concentration: hyper.concentration,
                        gamma: hyper.root,
                        gamma_bar: hyper.root * vocab as f64,
                        m,
                        s,
                    }) as Box<dyn ServingFamily>
                })
                .collect())
        }
        ModelKind::AliasHdp => {
            let hyper: TableHyper = need_tables()?;
            let vocab = (meta.vocab_size as usize).max(max_word(stores, &[0]));
            anyhow::ensure!(vocab > 0, "snapshot contains no word rows");
            // The root table row is K-sized prior state shared by every
            // slice (never vocabulary-filtered) — built once, cloned.
            let tables = Merged::build_parts(stores, 1, 1, k, 1, &|_| 0)
                .pop()
                .expect("one part requested");
            let root: Vec<i64> = (0..k).map(|t| tables.count(0, t) as i64).collect();
            let root_total = root.iter().sum::<i64>() as f64;
            Ok(Merged::build_parts(stores, 0, vocab, k, parts, owner)
                .into_iter()
                .map(|n| {
                    Box::new(HdpFamily {
                        k,
                        vocab,
                        b0: hyper.root,
                        b1: hyper.concentration,
                        beta: meta.beta,
                        beta_bar: meta.beta * vocab as f64,
                        n,
                        root: root.clone(),
                        root_total,
                    }) as Box<dyn ServingFamily>
                })
                .collect())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(model: &str, k: u32, tables: Option<TableHyper>) -> SnapshotMeta {
        SnapshotMeta {
            model: model.to_string(),
            k,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 10,
            slot: 0,
            n_servers: 1,
            vnodes: 8,
            iterations: 1,
            run_id: 0,
            tables,
        }
    }

    fn pdp_hyper() -> TableHyper {
        TableHyper {
            discount: 0.1,
            concentration: 10.0,
            root: 0.5,
        }
    }

    fn hdp_hyper() -> TableHyper {
        TableHyper {
            discount: 0.0,
            concentration: 1.0,
            root: 1.0,
        }
    }

    /// Consistent PDP stores: every word has customers in one topic with
    /// table counts below the customer counts.
    fn pdp_stores() -> Vec<Store> {
        let mut s = Store::new();
        for w in 0..10u32 {
            let (m_row, s_row) = if w < 5 {
                (vec![40, 0], vec![4, 0])
            } else {
                (vec![0, 40], vec![0, 4])
            };
            s.insert((0, w), m_row.into());
            s.insert((1, w), s_row.into());
        }
        vec![s]
    }

    #[test]
    fn lda_family_phi_normalizes() {
        let mut s = Store::new();
        for w in 0..10u32 {
            s.insert((0, w), if w < 5 { vec![7, 0] } else { vec![0, 7] }.into());
        }
        let fam = family_from_stores(&meta("AliasLDA", 2, None), &[s]).unwrap();
        assert_eq!(fam.kind(), ModelKind::AliasLda);
        assert_eq!(fam.total_tokens(), 70);
        for t in 0..2 {
            let sum: f64 = (0..10).map(|w| fam.phi(w, t)).sum();
            assert!((sum - 1.0).abs() < 1e-9, "LDA φ(·|{t}) sums to {sum}");
            assert!((fam.doc_prior(t) - 0.1).abs() < 1e-15);
        }
    }

    #[test]
    fn pdp_family_phi_normalizes() {
        let fam =
            family_from_stores(&meta("AliasPDP", 2, Some(pdp_hyper())), &pdp_stores())
                .unwrap();
        assert_eq!(fam.kind(), ModelKind::AliasPdp);
        // PYP predictive sums to 1 over the vocabulary when the table
        // polytope holds (Σ_w (m−a·s)⁺ = m_t − a·s_t and the root base
        // measure normalizes with γ̄ = γV).
        for t in 0..2 {
            let sum: f64 = (0..10).map(|w| fam.phi(w, t)).sum();
            assert!((sum - 1.0).abs() < 1e-9, "PDP φ(·|{t}) sums to {sum}");
        }
        // Tables sharpen: a word with customers dominates a smoothed zero.
        assert!(fam.phi(0, 0) > 10.0 * fam.phi(0, 1));
    }

    #[test]
    fn hdp_family_prior_follows_root_tables() {
        let mut s = Store::new();
        for w in 0..10u32 {
            s.insert((0, w), if w < 5 { vec![30, 0, 0] } else { vec![0, 30, 0] }.into());
        }
        s.insert((1, 0), vec![6, 2, 0].into()); // root: topic 0 has 3× topic 1
        let fam =
            family_from_stores(&meta("AliasHDP", 3, Some(hdp_hyper())), &[s]).unwrap();
        assert_eq!(fam.kind(), ModelKind::AliasHdp);
        for t in 0..3 {
            let sum: f64 = (0..10).map(|w| fam.phi(w, t)).sum();
            assert!((sum - 1.0).abs() < 1e-9, "HDP φ(·|{t}) sums to {sum}");
        }
        let p0 = fam.doc_prior(0);
        let p1 = fam.doc_prior(1);
        let p2 = fam.doc_prior(2);
        assert!((p0 / p1 - 3.0).abs() < 1e-6, "prior ratio {}", p0 / p1);
        assert!(p2 < 1e-8, "unrepresented topic must get ≈0 prior ({p2})");
    }

    #[test]
    fn pdp_without_v3_tables_is_refused() {
        let msg = match family_from_stores(&meta("AliasPDP", 2, None), &pdp_stores()) {
            Ok(_) => panic!("v2-era PDP snapshot must be refused"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("re-train"), "unhelpful error: {msg}");
    }

    #[test]
    fn unknown_family_is_refused() {
        let msg = match family_from_stores(&meta("GPT", 2, None), &[Store::new()]) {
            Ok(_) => panic!("unknown family must be refused"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("GPT"));
    }

    #[test]
    fn sliced_family_keeps_global_normalizers() {
        let mut s = Store::new();
        for w in 0..10u32 {
            s.insert((0, w), if w < 5 { vec![7, 0] } else { vec![0, 7] }.into());
        }
        let meta = meta("AliasLDA", 2, None);
        let full = family_from_stores(&meta, std::slice::from_ref(&s)).unwrap();
        let keep = |w: u32| w % 2 == 0;
        let half =
            family_from_stores_sliced(&meta, std::slice::from_ref(&s), Some(&keep)).unwrap();
        for w in 0..10u32 {
            assert_eq!(half.has_row(w), keep(w), "slice must own exactly its words");
            for t in 0..2 {
                if keep(w) {
                    // Bit-identical: same counts, same (global) totals.
                    assert_eq!(
                        half.phi(w, t).to_bits(),
                        full.phi(w, t).to_bits(),
                        "sliced φ({w},{t}) drifted"
                    );
                } else {
                    // Non-owned word reads as never-observed (smoothed 0,
                    // never above the full model's value).
                    assert!(half.phi(w, t) <= full.phi(w, t));
                }
            }
        }
        // HDP: the root row survives slicing even when word 0 is not owned.
        let mut h = Store::new();
        for w in 0..10u32 {
            h.insert((0, w), if w < 5 { vec![30, 0, 0] } else { vec![0, 30, 0] }.into());
        }
        h.insert((1, 0), vec![6, 2, 0].into());
        let hmeta = meta_hdp();
        let full = family_from_stores(&hmeta, std::slice::from_ref(&h)).unwrap();
        let none = |_w: u32| false;
        let empty =
            family_from_stores_sliced(&hmeta, std::slice::from_ref(&h), Some(&none)).unwrap();
        for t in 0..3 {
            assert_eq!(
                empty.doc_prior(t).to_bits(),
                full.doc_prior(t).to_bits(),
                "root-stick prior must be slice-independent"
            );
        }
    }

    fn meta_hdp() -> SnapshotMeta {
        meta("AliasHDP", 3, Some(hdp_hyper()))
    }

    /// Satellite: the single-scan partitioned build is bit-identical to
    /// the per-part filtered builds it replaces — for every family,
    /// including the PDP's paired matrices and the HDP's shared root row.
    #[test]
    fn partitioned_build_matches_per_part_sliced_builds() {
        let parts = 3usize;
        let owner = |w: u32| (w * 7 + 1) % parts as u32;
        let mut lda_store = Store::new();
        let mut hdp_store = Store::new();
        for w in 0..10u32 {
            lda_store.insert((0, w), if w < 5 { vec![7, 0] } else { vec![-2, 7] }.into());
            hdp_store.insert((0, w), if w < 5 { vec![30, 0, 0] } else { vec![0, 30, 0] }.into());
        }
        hdp_store.insert((1, 0), vec![6, 2, 0].into());
        let cases: Vec<(SnapshotMeta, Vec<Store>)> = vec![
            (meta("AliasLDA", 2, None), vec![lda_store]),
            (meta("AliasPDP", 2, Some(pdp_hyper())), pdp_stores()),
            (meta("AliasHDP", 3, Some(hdp_hyper())), vec![hdp_store]),
        ];
        for (m, stores) in cases {
            let fams = families_from_stores_partitioned(&m, &stores, parts, &owner).unwrap();
            assert_eq!(fams.len(), parts);
            for (p, fam) in fams.iter().enumerate() {
                let keep = |w: u32| owner(w) == p as u32;
                let sliced = family_from_stores_sliced(&m, &stores, Some(&keep)).unwrap();
                assert_eq!(fam.kind(), sliced.kind());
                assert_eq!(fam.total_tokens(), sliced.total_tokens(), "{} part {p}", m.model);
                for w in 0..fam.vocab() as u32 {
                    assert_eq!(
                        fam.has_row(w),
                        sliced.has_row(w),
                        "{} part {p} word {w} ownership",
                        m.model
                    );
                    for t in 0..fam.k() {
                        assert_eq!(
                            fam.phi(w, t).to_bits(),
                            sliced.phi(w, t).to_bits(),
                            "{} part {p} φ({w},{t})",
                            m.model
                        );
                    }
                }
                for t in 0..fam.k() {
                    assert_eq!(
                        fam.doc_prior(t).to_bits(),
                        sliced.doc_prior(t).to_bits(),
                        "{} part {p} prior({t})",
                        m.model
                    );
                }
            }
        }
    }

    #[test]
    fn merge_adds_across_slots_and_clamps_negatives() {
        let mut a = Store::new();
        a.insert((0, 1), vec![3, -5].into());
        let mut b = Store::new();
        b.insert((0, 1), vec![1, 2].into());
        b.insert((0, 2), vec![0, 4].into());
        let stores = [a, b];
        let m = Merged::build_parts(&stores, 0, 10, 2, 1, &|_| 0)
            .pop()
            .unwrap();
        assert_eq!(m.count(1, 0), 4);
        assert_eq!(m.count(1, 1), 0, "negative cells clamp to 0 on read");
        assert_eq!(m.count(2, 1), 4);
        // Totals clamp per-entry: the −3 in (1,1) does not cancel (2,1).
        assert_eq!(m.totals[1], 4);
        // A partitioned build lands each row on its owner only, and every
        // part keeps the identical (global, clamped) totals.
        let parts = Merged::build_parts(&stores, 0, 10, 2, 2, &|w| u32::from(w != 2));
        let (kept, dropped) = (&parts[0], &parts[1]);
        assert!(!kept.has_row(1) && kept.has_row(2));
        assert!(dropped.has_row(1) && !dropped.has_row(2));
        assert_eq!(kept.totals, m.totals);
        assert_eq!(dropped.totals, m.totals);
        assert_eq!(kept.count(2, 1), 4);
        assert_eq!(kept.count(1, 0), 0, "unowned row reads as absent");
    }
}
