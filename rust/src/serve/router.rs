//! Multi-replica serving: a consistent-hash query router over a set of
//! vocabulary-sliced replicas.
//!
//! The paper's serving-scale story mirrors its training-scale story:
//! partition the model state over machines with consistent hashing (§4's
//! Chord-style ring, [`crate::ps::ring`]) so no single node holds the
//! whole word–topic matrix. This module carries that layout into the
//! inference tier:
//!
//! * [`QueryRouter`] — the vocabulary partition: word `w` is owned by
//!   exactly one of `N` replicas (`ring.route(0, w)`), the same
//!   mechanism the training parameter server uses for its keys. Growing
//!   the set `N → N+1` only moves the ~`1/(N+1)` of words the new
//!   replica's arcs capture; ownership between existing replicas never
//!   changes.
//! * [`ReplicaSet`] — `N` [`Replica`]s, each holding a
//!   [`ServingModel`] *slice* (only its owned words' rows, all
//!   normalizers global — see
//!   [`ServingModel::from_stores_sliced`]) with its own budgeted alias
//!   LRU, so replicas never contend on a shared cache lock.
//! * [`SetGeneration`] — one committed, immutable view of the set. A
//!   query **scatters** its words to the owning replicas, **gathers**
//!   their `prior_t·φ(w,t)` proposals, and runs the MH-Walker fold-in
//!   ([`super::infer::infer_with_proposals`]) against the merged
//!   proposal. Slices are bit-identical to the full model for owned
//!   words and the fold-in consumes the RNG identically, so the routed
//!   posterior is **exactly** the single-replica posterior under a fixed
//!   seed.
//!
//! Reloads are two-phase: every replica *prepares* (loads, slices,
//! pre-warms from its outgoing resident set, stages) and only then does
//! the set *commit* — one atomic swap that makes the new generation
//! visible everywhere at once. A replica dropping mid-reload aborts the
//! commit; the set keeps serving the old generation with zero dropped
//! requests, and a later successful reload bumps the set-wide
//! generation.
//!
//! Membership changes ride the same generation machinery
//! ([`ReplicaSet::resize`]): a grow or shrink re-partitions the
//! vocabulary over a fresh router, re-slices the stores, and commits the
//! new topology as a new generation. Because every [`SetGeneration`]
//! carries its **own** router, a micro-batch that pinned the old
//! generation keeps scattering over the old membership until it
//! finishes — resizing drops zero in-flight queries.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::handle::{PinnedGeneration, QueryBackend};
use super::infer::{infer_with_proposals, InferConfig, InferResult};
use super::model::{ReloadStats, ResidentStores, ServingModel, DEFAULT_CACHE_BYTES};
use super::replica::Replica;
use crate::config::ModelKind;
use crate::ps::ring::Ring;
use crate::ps::snapshot::{SnapshotMeta, Store};
use crate::util::rng::Rng;
use crate::Result;

/// Virtual ring points per replica. More than the training default:
/// serving replicas are few and long-lived, and a finer ring tightens
/// both the load balance and the `1/(N+1)` resize-remap bound.
pub const REPLICA_VNODES: usize = 128;

/// Matrix id the vocabulary is routed by — the primary word–topic
/// statistic. Table-side rows (PDP `s_tw`) follow their word, so a
/// word's statistics always live together on one replica.
const ROUTE_MATRIX: u8 = 0;

/// Documents at least this long gather their per-replica proposals on
/// concurrent scoped threads; shorter ones stay on the calling thread,
/// where the fan-out costs more than the cache lookups it parallelizes.
const CONCURRENT_GATHER_MIN_TOKENS: usize = 64;

/// The vocabulary partition: which replica owns which word.
#[derive(Clone, Debug)]
pub struct QueryRouter {
    ring: Ring,
}

impl QueryRouter {
    /// A router over `replicas` slots (≥ 1).
    pub fn new(replicas: usize) -> QueryRouter {
        QueryRouter {
            ring: Ring::new(replicas.max(1), REPLICA_VNODES),
        }
    }

    /// Number of replicas routed over.
    pub fn replicas(&self) -> usize {
        self.ring.slots()
    }

    /// The replica that owns word `w`.
    #[inline]
    pub fn owner(&self, w: u32) -> u32 {
        self.ring.route(ROUTE_MATRIX, w)
    }

    /// Partition `0..vocab` into per-replica owned-word lists (ascending
    /// within each replica). Total and disjoint by construction — the
    /// property the router test suite checks against [`Self::owner`].
    pub fn partition(&self, vocab: usize) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); self.replicas()];
        for w in 0..vocab as u32 {
            out[self.owner(w) as usize].push(w);
        }
        out
    }

    /// Per-replica owned-word counts over `0..vocab` — the load-balance
    /// diagnostic behind `serve --replicas N`'s topology report (a thin
    /// wrapper over [`Ring::spread`]).
    pub fn spread(&self, vocab: usize) -> Vec<usize> {
        self.ring.spread(ROUTE_MATRIX, vocab)
    }

    /// Scatter a document: token *indices* grouped by owning replica
    /// (replicas without any of the document's words get an empty list).
    pub fn scatter(&self, tokens: &[u32]) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.replicas()];
        for (i, &w) in tokens.iter().enumerate() {
            out[self.owner(w) as usize].push(i);
        }
        out
    }
}

/// One committed generation of a [`ReplicaSet`]: the router plus every
/// replica's slice, immutable until dropped. Old generations stay alive
/// for micro-batches that pinned them across a swap.
pub struct SetGeneration {
    /// Monotonic set-wide generation (1 = the initially loaded set).
    pub generation: u64,
    router: Arc<QueryRouter>,
    models: Vec<Arc<ServingModel>>,
}

impl SetGeneration {
    /// Per-replica slices (index = replica id).
    pub fn models(&self) -> &[Arc<ServingModel>] {
        &self.models
    }

    /// The router this generation scatters with.
    pub fn router(&self) -> &QueryRouter {
        &self.router
    }

    /// Scatter `tokens` to their owning replicas, gather each word's
    /// `prior_t·φ(w,t)` proposal, and fold the document in against the
    /// merged proposal. Bit-identical to
    /// [`infer_doc`](super::infer::infer_doc) on the unsliced model
    /// under the same `rng` seed; [`InferResult::served_by`] lists the
    /// replicas that contributed (ascending).
    pub fn infer_doc(&self, tokens: &[u32], cfg: &InferConfig, rng: &mut Rng) -> InferResult {
        let scatter = self.router.scatter(tokens);
        let busy: Vec<usize> = scatter
            .iter()
            .enumerate()
            .filter(|(_, idx)| !idx.is_empty())
            .map(|(r, _)| r)
            .collect();
        let served_by: Vec<u32> = busy.iter().map(|&r| r as u32).collect();
        let mut gathered: Vec<Option<Arc<super::cache::WordProposal>>> =
            vec![None; tokens.len()];
        if busy.len() >= 2 && tokens.len() >= CONCURRENT_GATHER_MIN_TOKENS {
            // Concurrent gather: one scoped thread per busy replica, each
            // resolving only its own slice's words against its own alias
            // cache (per-replica locks — no contention across threads).
            // Proposal resolution never touches `rng`, and the results
            // are merged back by token index, so the fold-in below
            // consumes `rng` exactly as the sequential path does: the
            // routed answer stays bit-identical to single-replica.
            let parts: Vec<Vec<(usize, Arc<super::cache::WordProposal>)>> =
                std::thread::scope(|s| {
                    let handles: Vec<_> = busy
                        .iter()
                        .map(|&r| {
                            let indices = &scatter[r];
                            let slice = &self.models[r];
                            s.spawn(move || {
                                indices
                                    .iter()
                                    .map(|&i| (i, slice.proposal(tokens[i])))
                                    .collect()
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("gather thread panicked"))
                        .collect()
                });
            for part in parts {
                for (i, p) in part {
                    gathered[i] = Some(p);
                }
            }
        } else {
            for &r in &busy {
                let slice = &self.models[r];
                for &i in &scatter[r] {
                    gathered[i] = Some(slice.proposal(tokens[i]));
                }
            }
        }
        let proposals: Vec<_> = gathered.into_iter().flatten().collect();
        debug_assert_eq!(proposals.len(), tokens.len(), "scatter lost a token");
        // Priors and totals are global state, bit-identical on every
        // slice — read them from replica 0.
        let primary = &self.models[0];
        let mut res = infer_with_proposals(
            primary.k(),
            primary.priors(),
            primary.prior_total(),
            &proposals,
            cfg,
            rng,
        );
        res.generation = self.generation;
        res.served_by = served_by;
        res
    }
}

impl PinnedGeneration for SetGeneration {
    fn generation(&self) -> u64 {
        self.generation
    }

    fn infer(&self, tokens: &[u32], cfg: &InferConfig, rng: &mut Rng) -> InferResult {
        self.infer_doc(tokens, cfg, rng)
    }
}

/// `N` vocabulary-sliced replicas behind one query router, with
/// generation-numbered set-wide hot reload. The replica partition is
/// independent of the *training* ring (`meta.n_servers`): the set
/// re-partitions the merged statistics over its own ring, so any replica
/// count can serve any snapshot directory.
pub struct ReplicaSet {
    /// Current membership's router. Swapped (with `replicas`, under the
    /// same commit) by [`resize`](Self::resize); reloads reuse it.
    router: RwLock<Arc<QueryRouter>>,
    replicas: RwLock<Vec<Arc<Replica>>>,
    current: RwLock<Arc<SetGeneration>>,
    /// Next set-wide generation number to hand out.
    next_gen: AtomicU64,
    /// Alias-cache budget **per replica**.
    cache_bytes: usize,
    /// The directory backing this set (None for in-memory sets).
    dir: Mutex<Option<PathBuf>>,
    /// Decoded stores of the last committed load — the generation-diff
    /// reload cache (None until a v4 directory loads, cleared on any
    /// reload error). Held across the whole reload, which also
    /// serializes concurrent reloads against each other.
    resident: Mutex<Option<ResidentStores>>,
    /// How the last successful directory load actually loaded.
    last_reload: Mutex<ReloadStats>,
}

impl ReplicaSet {
    /// Load a snapshot directory into `replicas` slices with the default
    /// per-replica cache budget.
    pub fn load_dir(dir: &Path, replicas: usize) -> Result<Arc<ReplicaSet>> {
        Self::load_dir_with_budget(dir, replicas, DEFAULT_CACHE_BYTES)
    }

    /// Load with an explicit per-replica alias-cache byte budget.
    pub fn load_dir_with_budget(
        dir: &Path,
        replicas: usize,
        cache_bytes: usize,
    ) -> Result<Arc<ReplicaSet>> {
        let mut resident = None;
        let (meta, stores, stats) = ServingModel::load_dir_stores_cached(dir, &mut resident)?;
        let set = Self::build(meta, &stores, replicas, cache_bytes)?;
        *set.dir.lock().unwrap() = Some(dir.to_path_buf());
        *set.resident.lock().unwrap() = resident;
        *set.last_reload.lock().unwrap() = stats;
        Ok(set)
    }

    /// Build from already-decoded stores (tests, tools, synthetic sets).
    pub fn from_stores(
        meta: SnapshotMeta,
        stores: Vec<Store>,
        replicas: usize,
        cache_bytes: usize,
    ) -> Result<Arc<ReplicaSet>> {
        Self::build(meta, &stores, replicas, cache_bytes)
    }

    fn build(
        meta: SnapshotMeta,
        stores: &[Store],
        replicas: usize,
        cache_bytes: usize,
    ) -> Result<Arc<ReplicaSet>> {
        anyhow::ensure!(replicas >= 1, "a replica set needs at least one replica");
        let router = Arc::new(QueryRouter::new(replicas));
        // All N slices from one scan of the stores (not one scan per
        // replica): rows land on their owner, normalizers stay global.
        let models: Vec<Arc<ServingModel>> =
            ServingModel::slices_from_stores(meta, stores, cache_bytes, replicas, &|w| {
                router.owner(w)
            })?
            .into_iter()
            .map(Arc::new)
            .collect();
        let replicas_vec: Vec<Arc<Replica>> = models
            .iter()
            .enumerate()
            .map(|(r, m)| Arc::new(Replica::new(r as u32, m.clone())))
            .collect();
        Ok(Arc::new(ReplicaSet {
            current: RwLock::new(Arc::new(SetGeneration {
                generation: 1,
                router: router.clone(),
                models,
            })),
            router: RwLock::new(router),
            replicas: RwLock::new(replicas_vec),
            next_gen: AtomicU64::new(2),
            cache_bytes,
            dir: Mutex::new(None),
            resident: Mutex::new(None),
            last_reload: Mutex::new(ReloadStats::default()),
        }))
    }

    /// Number of replicas in the current membership.
    pub fn replicas(&self) -> usize {
        self.replicas.read().unwrap().len()
    }

    /// One replica of the current membership, for stats and fault
    /// injection (panics on a bad id).
    pub fn replica(&self, id: usize) -> Arc<Replica> {
        self.replicas.read().unwrap()[id].clone()
    }

    /// The current membership's vocabulary router. Reloads keep it;
    /// [`resize`](Self::resize) replaces it. Generations pin their own
    /// copy, so holders of a [`SetGeneration`] never observe the swap.
    pub fn router(&self) -> Arc<QueryRouter> {
        self.router.read().unwrap().clone()
    }

    /// The committed generation. Hold the result for the duration of a
    /// batch so a concurrent set-wide swap can't change the topology
    /// mid-batch.
    pub fn current(&self) -> Arc<SetGeneration> {
        self.current.read().unwrap().clone()
    }

    /// The currently-visible set-wide generation number.
    pub fn generation(&self) -> u64 {
        self.current.read().unwrap().generation
    }

    /// The snapshot directory backing this set, if any.
    pub fn dir(&self) -> Option<PathBuf> {
        self.dir.lock().unwrap().clone()
    }

    /// Route one document through the committed generation —
    /// bit-identical to single-replica [`infer_doc`] on the unsliced
    /// model under the same seed.
    ///
    /// [`infer_doc`]: super::infer::infer_doc
    pub fn infer(&self, tokens: &[u32], cfg: &InferConfig, rng: &mut Rng) -> InferResult {
        self.current().infer_doc(tokens, cfg, rng)
    }

    /// Two-phase set reload from already-decoded stores. Phase 1
    /// prepares every replica in turn (slice + pre-warm + stage,
    /// [`Replica::prepare`]); any failure — including an injected fault —
    /// aborts with the old generation untouched. Phase 2 commits the
    /// staged slices set-wide in one swap. Returns the new set
    /// generation.
    pub fn install_stores(&self, meta: SnapshotMeta, stores: &[Store]) -> Result<u64> {
        let outgoing = self.current();
        // Refuse family/shape mismatches *before* phase 1: the N slice
        // builds and pre-warms are pure waste on a directory that can
        // never commit (e.g. `--watch` pointed at a retrained-as-PDP
        // dir would otherwise rebuild every replica each poll cycle).
        // Every committed generation passed this same check, so the
        // commit below only needs the monotonicity guard.
        Self::ensure_compatible(&outgoing, &meta)?;
        // Snapshot the membership once: a concurrent resize commits a
        // newer generation and the monotonicity guard below discards
        // this (now stale-topology) load.
        let router = self.router();
        let replicas: Vec<Arc<Replica>> = self.replicas.read().unwrap().clone();
        // One shared scan builds every replica's next slice; each replica
        // then prepares (fault check + pre-warm + stage) individually.
        let slices = ServingModel::slices_from_stores(
            meta,
            stores,
            self.cache_bytes,
            replicas.len(),
            &|w| router.owner(w),
        )
        .map_err(|e| {
            anyhow::anyhow!(
                "set reload aborted (still serving generation {}): {e}",
                outgoing.generation
            )
        })?;
        let mut fresh = Vec::with_capacity(replicas.len());
        for ((r, replica), slice) in replicas.iter().enumerate().zip(slices) {
            let slice = replica
                .prepare(Arc::new(slice), &outgoing.models[r])
                .map_err(|e| {
                    anyhow::anyhow!(
                        "set reload aborted (still serving generation {}): {e}",
                        outgoing.generation
                    )
                })?;
            fresh.push(slice);
        }
        // Commit set-wide: one atomic swap publishes every staged slice.
        let generation = self.next_gen.fetch_add(1, Ordering::SeqCst);
        let next = Arc::new(SetGeneration {
            generation,
            router,
            models: fresh,
        });
        let mut cur = self.current.write().unwrap();
        anyhow::ensure!(
            generation > cur.generation,
            "set reload superseded: generation {} was committed \
             concurrently and is newer; this load was discarded",
            cur.generation
        );
        *cur = next;
        Ok(generation)
    }

    /// Refuse a snapshot whose family or shape cannot replace what the
    /// set is serving (shared by reloads and resizes).
    fn ensure_compatible(outgoing: &SetGeneration, meta: &SnapshotMeta) -> Result<()> {
        let incoming = ModelKind::parse(&meta.model).ok_or_else(|| {
            anyhow::anyhow!("snapshot records unknown model family {:?}", meta.model)
        })?;
        anyhow::ensure!(
            incoming.family_name() == outgoing.models[0].kind().family_name(),
            "cannot swap the serving family from {} to {} — start a new \
             replica set for a different family instead",
            outgoing.models[0].meta().model,
            meta.model
        );
        anyhow::ensure!(
            meta.k as usize == outgoing.models[0].k(),
            "cannot swap in a snapshot with a different topic count \
             (K {} → {}) — restart the set to change model shape",
            outgoing.models[0].k(),
            meta.k
        );
        Ok(())
    }

    /// Change the set's membership to `replicas` replicas (grow or
    /// shrink) from already-decoded stores, committing the new topology
    /// as a new generation.
    ///
    /// The vocabulary is re-partitioned over a fresh consistent-hash
    /// router — a grow `N → N+1` re-homes only ≈`1/(N+1)` of the words —
    /// and each surviving replica's alias cache is **selectively
    /// pre-warmed** with the resident words whose ownership did *not*
    /// move (their tables are still valid under the new topology; only
    /// the ≈`1/(N+1)` re-homed words start cold). Queries in flight keep
    /// the [`SetGeneration`] they pinned, which scatters over the *old*
    /// membership until the micro-batch finishes: a resize never drops a
    /// query. Returns the new set generation.
    pub fn resize_with_stores(
        &self,
        meta: SnapshotMeta,
        stores: &[Store],
        replicas: usize,
    ) -> Result<u64> {
        anyhow::ensure!(replicas >= 1, "a replica set needs at least one replica");
        let outgoing = self.current();
        Self::ensure_compatible(&outgoing, &meta)?;
        let router = Arc::new(QueryRouter::new(replicas));
        let models: Vec<Arc<ServingModel>> =
            ServingModel::slices_from_stores(meta, stores, self.cache_bytes, replicas, &|w| {
                router.owner(w)
            })
            .map_err(|e| {
                anyhow::anyhow!(
                    "resize aborted (still serving generation {} with {} replicas): {e}",
                    outgoing.generation,
                    outgoing.models.len()
                )
            })?
            .into_iter()
            .map(Arc::new)
            .collect();
        // Selective pre-warm: a replica that survives the resize keeps
        // owning every word the new router still maps to it, and those
        // words' alias tables are identical under the new topology. Carry
        // them over warm (coldest-first, as `resident_words` yields them)
        // so only the ≈1/(N+1) re-homed words pay a post-resize cache
        // miss — the p99 softener the ROADMAP carried.
        for (r, old) in outgoing.models.iter().enumerate() {
            if r >= models.len() {
                continue; // replica departs on a shrink
            }
            let kept: Vec<u32> = old
                .resident_words()
                .into_iter()
                .filter(|&w| router.owner(w) == r as u32)
                .collect();
            if !kept.is_empty() {
                models[r].prewarm_words(&kept);
            }
        }
        let fresh: Vec<Arc<Replica>> = models
            .iter()
            .enumerate()
            .map(|(r, m)| Arc::new(Replica::new(r as u32, m.clone())))
            .collect();
        let generation = self.next_gen.fetch_add(1, Ordering::SeqCst);
        let next = Arc::new(SetGeneration {
            generation,
            router: router.clone(),
            models,
        });
        // Commit the topology and the generation under the same write
        // lock so `router()`/`replica()` always describe the committed
        // generation.
        let mut cur = self.current.write().unwrap();
        anyhow::ensure!(
            generation > cur.generation,
            "resize superseded: generation {} was committed concurrently \
             and is newer; this resize was discarded",
            cur.generation
        );
        *cur = next;
        *self.router.write().unwrap() = router;
        *self.replicas.write().unwrap() = fresh;
        Ok(generation)
    }

    /// [`resize_with_stores`](Self::resize_with_stores) re-slicing the
    /// snapshot directory backing this set (the live grow/shrink path
    /// for dir-loaded sets).
    pub fn resize(&self, replicas: usize) -> Result<u64> {
        let dir = self
            .dir()
            .ok_or_else(|| anyhow::anyhow!("replica set has no backing snapshot directory"))?;
        let (meta, stores) = ServingModel::load_dir_stores(&dir)?;
        self.resize_with_stores(meta, &stores, replicas)
    }

    /// Reload a (presumably newer) snapshot directory into every replica
    /// and commit set-wide. The expensive part (decode + N slice builds +
    /// pre-warms) runs on the caller's thread with no serving lock held;
    /// on error the set keeps serving its current generation untouched
    /// (and the diff cache is dropped so the next attempt decodes from
    /// scratch). A v4 directory extending the resident cache's segment
    /// watermarks loads `O(delta)` — only the segments written since the
    /// previous load are read — and commits through the same
    /// [`install_stores`](Self::install_stores) terminal path as a full
    /// decode, so the served generation is bit-identical either way.
    pub fn reload(&self, dir: &Path) -> Result<u64> {
        let mut resident = self.resident.lock().unwrap();
        let loaded: Result<(u64, ReloadStats)> = (|| {
            let (meta, stores, stats) = ServingModel::load_dir_stores_cached(dir, &mut resident)?;
            let generation = self.install_stores(meta, &stores)?;
            Ok((generation, stats))
        })();
        match loaded {
            Ok((generation, stats)) => {
                *self.dir.lock().unwrap() = Some(dir.to_path_buf());
                *self.last_reload.lock().unwrap() = stats;
                Ok(generation)
            }
            Err(e) => {
                *resident = None;
                Err(e)
            }
        }
    }

    /// How the last successful directory load actually loaded: a full
    /// decode, or a generation-diff overlay (and of how many segments /
    /// rows). The `serve --watch` loop logs this per reload.
    pub fn last_reload_stats(&self) -> ReloadStats {
        *self.last_reload.lock().unwrap()
    }

    /// [`reload`](Self::reload) from the directory this set was last
    /// loaded from (the `serve --watch --replicas N` path).
    pub fn reload_latest(&self) -> Result<u64> {
        let dir = self
            .dir()
            .ok_or_else(|| anyhow::anyhow!("replica set has no backing snapshot directory"))?;
        self.reload(&dir)
    }
}

impl QueryBackend for ReplicaSet {
    fn pin(&self) -> Arc<dyn PinnedGeneration> {
        self.current()
    }

    fn generation(&self) -> u64 {
        ReplicaSet::generation(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::infer::infer_doc;

    fn toy_meta() -> SnapshotMeta {
        SnapshotMeta {
            model: "AliasLDA".to_string(),
            k: 2,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 20,
            slot: 0,
            n_servers: 1,
            vnodes: 8,
            iterations: 1,
            run_id: 0,
            tables: None,
        }
    }

    fn toy_stores(weight: i32) -> Vec<Store> {
        let mut s = Store::new();
        for w in 0..20u32 {
            s.insert((0, w), if w < 10 { vec![weight, 0] } else { vec![0, weight] }.into());
        }
        vec![s]
    }

    #[test]
    fn partition_covers_vocab_exactly_once() {
        let router = QueryRouter::new(3);
        let parts = router.partition(1000);
        assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), 1000);
        let mut seen = vec![false; 1000];
        for (r, part) in parts.iter().enumerate() {
            for &w in part {
                assert_eq!(router.owner(w), r as u32);
                assert!(!seen[w as usize], "word {w} owned twice");
                seen[w as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn routed_matches_single_replica_bitwise() {
        let single =
            ServingModel::from_stores(toy_meta(), toy_stores(50), 1 << 20).unwrap();
        let set = ReplicaSet::from_stores(toy_meta(), toy_stores(50), 3, 1 << 20).unwrap();
        let doc: Vec<u32> = (0..30).map(|i| (i % 20) as u32).collect();
        let cfg = InferConfig::default();
        let a = infer_doc(&single, &doc, &cfg, &mut Rng::new(77));
        let b = set.infer(&doc, &cfg, &mut Rng::new(77));
        assert_eq!(a.theta.len(), b.theta.len());
        for (x, y) in a.theta.iter().zip(b.theta.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "routed θ diverged");
        }
        assert!(!b.served_by.is_empty());
        assert_eq!(b.generation, 1);
    }

    #[test]
    fn install_commits_set_wide_and_fault_aborts() {
        let set = ReplicaSet::from_stores(toy_meta(), toy_stores(50), 2, 1 << 20).unwrap();
        assert_eq!(set.generation(), 1);
        // Injected fault on replica 1 → whole commit aborts.
        set.replica(1).fail_next_reload();
        let msg = match set.install_stores(toy_meta(), &toy_stores(60)) {
            Ok(_) => panic!("faulted prepare must abort the commit"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("still serving generation 1"), "{msg}");
        assert_eq!(set.generation(), 1, "aborted reload must not swap");
        // Fault is one-shot: the retry commits set-wide.
        let g = set.install_stores(toy_meta(), &toy_stores(60)).unwrap();
        assert_eq!(g, 2);
        assert_eq!(set.generation(), 2);
        for m in set.current().models() {
            assert_eq!(m.total_tokens(), 20 * 60);
        }
    }

    #[test]
    fn resize_commits_new_membership_and_keeps_pinned_generations() {
        let set = ReplicaSet::from_stores(toy_meta(), toy_stores(50), 2, 1 << 20).unwrap();
        let doc: Vec<u32> = (0..30).map(|i| (i % 20) as u32).collect();
        let cfg = InferConfig::default();
        let single =
            ServingModel::from_stores(toy_meta(), toy_stores(50), 1 << 20).unwrap();
        let want = infer_doc(&single, &doc, &cfg, &mut Rng::new(7));

        // Pin the 2-replica generation, as an in-flight micro-batch would.
        let pinned = set.current();

        let g = set.resize_with_stores(toy_meta(), &toy_stores(50), 3).unwrap();
        assert_eq!(g, 2);
        assert_eq!(set.replicas(), 3);
        assert_eq!(set.router().replicas(), 3);

        // The pinned generation still scatters over the old 2-way
        // membership — nothing in flight is dropped by the resize.
        let old = pinned.infer_doc(&doc, &cfg, &mut Rng::new(7));
        assert_eq!(old.generation, 1);
        assert!(old.served_by.iter().all(|&r| r < 2), "{:?}", old.served_by);
        for (x, y) in want.theta.iter().zip(old.theta.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "pinned θ diverged");
        }
        // The grown membership answers bit-identically to the unsliced
        // model — routed correctness is invariant to the replica count.
        let grown = set.infer(&doc, &cfg, &mut Rng::new(7));
        assert_eq!(grown.generation, 2);
        for (x, y) in want.theta.iter().zip(grown.theta.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "resized θ diverged");
        }

        // Shrink to one replica: everything routes to replica 0.
        let g = set.resize_with_stores(toy_meta(), &toy_stores(50), 1).unwrap();
        assert_eq!(g, 3);
        assert_eq!(set.replicas(), 1);
        let solo = set.infer(&doc, &cfg, &mut Rng::new(7));
        assert_eq!(solo.served_by, vec![0]);
        for (x, y) in want.theta.iter().zip(solo.theta.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "shrunk θ diverged");
        }
    }

    #[test]
    fn concurrent_gather_matches_single_replica_bitwise() {
        // A document long enough to cross CONCURRENT_GATHER_MIN_TOKENS
        // spread over every replica exercises the scoped-thread gather —
        // and the answer must still be bit-identical to the unsliced
        // model, because proposal resolution never touches the RNG.
        let single =
            ServingModel::from_stores(toy_meta(), toy_stores(50), 1 << 20).unwrap();
        let set = ReplicaSet::from_stores(toy_meta(), toy_stores(50), 4, 1 << 20).unwrap();
        let doc: Vec<u32> = (0..CONCURRENT_GATHER_MIN_TOKENS * 3)
            .map(|i| (i % 20) as u32)
            .collect();
        let cfg = InferConfig::default();
        let a = infer_doc(&single, &doc, &cfg, &mut Rng::new(4242));
        let b = set.infer(&doc, &cfg, &mut Rng::new(4242));
        for (x, y) in a.theta.iter().zip(b.theta.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "concurrent-gather θ diverged");
        }
        // All replicas own some of the 20-word vocabulary here, so the
        // concurrent path (≥ 2 busy replicas) genuinely ran.
        assert!(b.served_by.len() >= 2, "served_by = {:?}", b.served_by);
        let mut sorted = b.served_by.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, b.served_by, "served_by must stay ascending");
    }

    #[test]
    fn resize_prewarms_only_words_that_kept_their_owner() {
        let set = ReplicaSet::from_stores(toy_meta(), toy_stores(50), 2, 1 << 20).unwrap();
        // Make every word's alias table resident in the outgoing
        // generation.
        let gen1 = set.current();
        for w in 0..20u32 {
            for m in gen1.models() {
                if m.has_row(w) {
                    m.proposal(w);
                }
            }
        }
        let old_router = set.router();
        let g = set.resize_with_stores(toy_meta(), &toy_stores(50), 3).unwrap();
        assert_eq!(g, 2);
        let new_router = set.router();
        let gen2 = set.current();
        for (r, m) in gen2.models().iter().enumerate().take(2) {
            let stats = m.cache_stats();
            // Words owned by r under BOTH routers were carried over warm.
            let kept = (0..20u32)
                .filter(|&w| {
                    old_router.owner(w) == r as u32 && new_router.owner(w) == r as u32
                })
                .count() as u64;
            assert_eq!(
                stats.prewarmed, kept,
                "replica {r}: prewarmed {} but {kept} words kept their owner",
                stats.prewarmed
            );
            assert_eq!(stats.misses, 0, "pre-warm must not count as misses");
            // And the pre-warmed words answer without a build: hits only.
            for w in 0..20u32 {
                if old_router.owner(w) == r as u32 && new_router.owner(w) == r as u32 {
                    m.proposal(w);
                }
            }
            let after = m.cache_stats();
            assert_eq!(after.misses, 0, "replica {r}: a kept word went cold");
            assert_eq!(after.hits, kept, "replica {r}: kept words must hit");
        }
    }

    #[test]
    fn v4_set_reload_takes_the_diff_path_and_stays_bit_identical() {
        use crate::ps::snapshot;
        let dir = std::env::temp_dir().join(format!(
            "hplvm_set_diff_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut store = toy_stores(50).remove(0);
        let mut log = snapshot::SegmentLog::new(0);
        log.seal_to(&dir, &store, &toy_meta()).unwrap();

        let set = ReplicaSet::load_dir(&dir, 3).unwrap();
        assert!(set.last_reload_stats().full, "first load decodes fully");

        // One changed row sealed as a delta → the set reload reads one
        // segment / one row and commits a generation bit-identical to
        // the unsliced full decode.
        store.insert((0, 7), vec![3, 4].into());
        log.mark_dirty((0, 7));
        log.seal_to(&dir, &store, &toy_meta()).unwrap();
        let g = set.reload_latest().unwrap();
        assert_eq!(g, 2);
        let st = set.last_reload_stats();
        assert_eq!((st.full, st.segments, st.rows), (false, 1, 1), "{st:?}");

        let single = ServingModel::load_dir(&dir).unwrap();
        let doc: Vec<u32> = (0..30).map(|i| (i % 20) as u32).collect();
        let cfg = InferConfig::default();
        let a = infer_doc(&single, &doc, &cfg, &mut Rng::new(91));
        let b = set.infer(&doc, &cfg, &mut Rng::new(91));
        for (x, y) in a.theta.iter().zip(b.theta.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "diff-reloaded θ diverged");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn install_refuses_family_and_shape_changes() {
        let set = ReplicaSet::from_stores(toy_meta(), toy_stores(50), 2, 1 << 20).unwrap();
        let mut wide = toy_meta();
        wide.k = 3;
        let mut s = Store::new();
        s.insert((0, 1), vec![1, 2, 3].into());
        assert!(set.install_stores(wide.clone(), &[s.clone()]).is_err());
        // Resizes apply the same family/shape guard.
        assert!(set.resize_with_stores(wide, &[s], 3).is_err());
        assert_eq!(set.generation(), 1);
        assert_eq!(set.replicas(), 2, "refused resize must not change membership");
    }
}
