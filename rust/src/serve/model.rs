//! The frozen serving model: global word–topic statistics merged from a
//! training snapshot directory.
//!
//! Training servers each snapshot their ring partition of the shared
//! `n_tw` matrix ([`crate::ps::snapshot`]); the slots' key sets are
//! disjoint by consistent hashing, so the global statistics are the
//! row-wise sum of every `server_slot*.snap` in the directory. The v2
//! snapshot header carries the hyperparameters (model, K, α, β) and the
//! ring geometry, making the directory fully self-describing — the
//! inference server needs no training config.

use std::path::Path;
use std::sync::Arc;

use super::cache::{AliasCache, CacheStats, WordProposal};
use crate::eval::perplexity::TopicModelView;
use crate::ps::ring::Ring;
use crate::ps::snapshot::{self, SnapshotMeta, Store};
use crate::sampler::alias::AliasTable;
use crate::Result;

/// Default alias-cache budget (64 MiB ≈ 3k resident tables at K=1024).
pub const DEFAULT_CACHE_BYTES: usize = 64 << 20;

/// Immutable global statistics + lazily-built per-word alias tables.
pub struct ServingModel {
    meta: SnapshotMeta,
    k: usize,
    alpha: f64,
    beta: f64,
    beta_bar: f64,
    vocab: usize,
    /// Merged `n_tw` rows (dense, `None` for words never observed).
    rows: Vec<Option<Box<[i32]>>>,
    /// Per-topic totals `n_t`.
    totals: Vec<i64>,
    cache: AliasCache,
}

impl ServingModel {
    /// Load and merge every `server_slot*.snap` under `dir` with the
    /// default cache budget.
    pub fn load_dir(dir: &Path) -> Result<ServingModel> {
        Self::load_dir_with_budget(dir, DEFAULT_CACHE_BYTES)
    }

    /// Load with an explicit alias-cache byte budget.
    pub fn load_dir_with_budget(dir: &Path, cache_bytes: usize) -> Result<ServingModel> {
        let mut slots: Vec<(Option<SnapshotMeta>, Store)> = Vec::new();
        let entries = std::fs::read_dir(dir)
            .map_err(|e| anyhow::anyhow!("cannot read snapshot dir {}: {e}", dir.display()))?;
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            if !(name.starts_with("server_slot") && name.ends_with(".snap")) {
                continue;
            }
            let bytes = snapshot::read_snapshot(&entry.path())
                .ok_or_else(|| anyhow::anyhow!("unreadable snapshot {name}"))?;
            let decoded = snapshot::decode_store_meta(&bytes)
                .ok_or_else(|| anyhow::anyhow!("corrupt snapshot {name}"))?;
            slots.push(decoded);
        }
        anyhow::ensure!(
            !slots.is_empty(),
            "no server_slot*.snap files in {} — train with --snapshot-dir first",
            dir.display()
        );
        let meta = slots
            .iter()
            .find_map(|(m, _)| m.clone())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "snapshots in {} predate the v2 format and carry no \
                     hyperparameters; re-train to serve them",
                    dir.display()
                )
            })?;
        // A v1 file next to v2 files is a stale slot from an earlier run:
        // it would dodge every consistency check below (no header to
        // compare), so refuse outright rather than merge mixed runs.
        anyhow::ensure!(
            slots.iter().all(|(m, _)| m.is_some()),
            "snapshot dir {} mixes v2 and pre-v2 slot files — stale \
             snapshots from an earlier run; re-train to regenerate",
            dir.display()
        );
        for (m, _) in slots.iter() {
            if let Some(m) = m {
                anyhow::ensure!(
                    m.k == meta.k && m.n_servers == meta.n_servers && m.vnodes == meta.vnodes,
                    "snapshot slots disagree on ring/model geometry \
                     (K {} vs {}, servers {} vs {})",
                    m.k,
                    meta.k,
                    m.n_servers,
                    meta.n_servers
                );
                // Same-geometry slots from *different runs* would merge
                // silently otherwise — the ring check can't catch them.
                anyhow::ensure!(
                    m.model == meta.model
                        && m.alpha.to_bits() == meta.alpha.to_bits()
                        && m.beta.to_bits() == meta.beta.to_bits()
                        && m.vocab_size == meta.vocab_size,
                    "snapshot slots disagree on hyperparameters \
                     ({} α={} β={} V={} vs {} α={} β={} V={}) — mixed runs?",
                    m.model,
                    m.alpha,
                    m.beta,
                    m.vocab_size,
                    meta.model,
                    meta.alpha,
                    meta.beta,
                    meta.vocab_size
                );
            }
        }
        anyhow::ensure!(
            slots.len() == meta.n_servers as usize,
            "expected {} slot snapshots, found {} — partial snapshot dir",
            meta.n_servers,
            slots.len()
        );
        // Ring-assignment sanity: every key must live in the slot that
        // owns its arc. A mismatch means mixed snapshot generations.
        let ring = Ring::new(meta.n_servers as usize, meta.vnodes as usize);
        let mut misrouted = 0u64;
        for (m, store) in slots.iter() {
            if let Some(m) = m {
                for &(matrix, word) in store.keys() {
                    if ring.route(matrix, word) != m.slot {
                        misrouted += 1;
                    }
                }
            }
        }
        if misrouted > 0 {
            crate::warn!(
                "serve",
                "{misrouted} snapshot keys routed outside their slot — \
                 snapshot dir may mix runs"
            );
        }
        Self::from_stores(meta, slots.into_iter().map(|(_, s)| s).collect(), cache_bytes)
    }

    /// Build from already-decoded stores (exposed for tests and tools).
    pub fn from_stores(
        meta: SnapshotMeta,
        stores: Vec<Store>,
        cache_bytes: usize,
    ) -> Result<ServingModel> {
        anyhow::ensure!(meta.k > 0, "snapshot metadata has K = 0");
        anyhow::ensure!(
            meta.model.contains("LDA"),
            "serving supports LDA-family snapshots (n_tw statistics); \
             got a {} snapshot — PDP/HDP serving is an open roadmap item",
            meta.model
        );
        let k = meta.k as usize;
        let max_word = stores
            .iter()
            .flat_map(|s| s.keys())
            .filter(|(m, _)| *m == 0)
            .map(|&(_, w)| w as usize + 1)
            .max()
            .unwrap_or(0);
        let vocab = (meta.vocab_size as usize).max(max_word);
        anyhow::ensure!(vocab > 0, "snapshot contains no word rows");
        let mut rows: Vec<Option<Box<[i32]>>> = vec![None; vocab];
        let mut totals = vec![0i64; k];
        for store in &stores {
            // Matrix 0 is `n_tw` for both LDA samplers (coordinator
            // layout); other matrices belong to PDP/HDP table stats.
            for (&(matrix, word), row) in store.iter() {
                if matrix != 0 {
                    continue;
                }
                let dst = rows[word as usize].get_or_insert_with(|| {
                    vec![0i32; k].into_boxed_slice()
                });
                for (t, &v) in row.iter().take(k).enumerate() {
                    dst[t] = dst[t].saturating_add(v);
                }
            }
        }
        for row in rows.iter().flatten() {
            for (t, &v) in row.iter().enumerate() {
                // Eventual consistency can leave transient negatives in a
                // snapshot; clamp at the aggregate like the samplers do.
                totals[t] += v.max(0) as i64;
            }
        }
        Ok(ServingModel {
            k,
            alpha: meta.alpha,
            beta: meta.beta,
            beta_bar: meta.beta * vocab as f64,
            vocab,
            rows,
            totals,
            cache: AliasCache::new(k, cache_bytes, 16),
            meta,
        })
    }

    /// Topic count.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Document-topic prior α.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Topic-word prior β.
    pub fn beta(&self) -> f64 {
        self.beta
    }

    /// Vocabulary size the model serves.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The snapshot metadata this model was loaded from.
    pub fn meta(&self) -> &SnapshotMeta {
        &self.meta
    }

    /// Total (clamped) token mass in the frozen statistics.
    pub fn total_tokens(&self) -> i64 {
        self.totals.iter().sum()
    }

    /// Alias-cache statistics.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    #[inline]
    fn count(&self, w: u32, t: usize) -> i32 {
        match self.rows.get(w as usize).and_then(|r| r.as_deref()) {
            Some(row) => row[t].max(0),
            None => 0,
        }
    }

    #[inline]
    fn denom(&self, t: usize) -> f64 {
        self.totals[t].max(0) as f64 + self.beta_bar
    }

    /// The word's frozen dense proposal, from the cache (built on miss).
    pub fn proposal(&self, w: u32) -> Arc<WordProposal> {
        self.cache.get_or_build(w, || {
            let mut qw = Vec::with_capacity(self.k);
            for t in 0..self.k {
                qw.push((self.count(w, t) as f64 + self.beta) / self.denom(t));
            }
            let qsum: f64 = qw.iter().sum();
            WordProposal {
                table: AliasTable::build(&qw),
                qw: qw.into_boxed_slice(),
                qsum,
            }
        })
    }
}

impl TopicModelView for ServingModel {
    fn k(&self) -> usize {
        self.k
    }

    fn phi(&self, w: u32, t: usize) -> f64 {
        (self.count(w, t) as f64 + self.beta) / self.denom(t)
    }

    fn doc_prior(&self, _t: usize) -> f64 {
        self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(k: u32, n_servers: u32) -> SnapshotMeta {
        SnapshotMeta {
            model: "AliasLDA".to_string(),
            k,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 10,
            slot: 0,
            n_servers,
            vnodes: 8,
            iterations: 1,
        }
    }

    #[test]
    fn merges_slot_stores() {
        let mut a = Store::new();
        a.insert((0, 1), vec![3, 0, 1]);
        let mut b = Store::new();
        b.insert((0, 2), vec![0, 5, 0]);
        b.insert((0, 1), vec![1, 0, 0]); // overlap adds
        b.insert((1, 2), vec![9, 9, 9]); // non-primary matrix ignored
        let m = ServingModel::from_stores(meta(3, 2), vec![a, b], 1 << 20).unwrap();
        assert_eq!(m.k(), 3);
        assert_eq!(m.vocab(), 10);
        assert_eq!(m.count(1, 0), 4);
        assert_eq!(m.count(2, 1), 5);
        assert_eq!(m.total_tokens(), 4 + 1 + 5);
        // φ normalizes against clamped totals.
        let phi_sum: f64 = (0..10).map(|w| m.phi(w, 1)).sum();
        assert!((phi_sum - 1.0).abs() < 1e-9, "φ(·|t) sums to {phi_sum}");
    }

    #[test]
    fn rejects_non_lda_and_empty() {
        let mut pdp = meta(4, 1);
        pdp.model = "AliasPDP".to_string();
        assert!(ServingModel::from_stores(pdp, vec![Store::new()], 1024).is_err());
        let mut zero_k = meta(0, 1);
        zero_k.vocab_size = 10;
        assert!(ServingModel::from_stores(zero_k, vec![Store::new()], 1024).is_err());
    }

    #[test]
    fn proposal_matches_phi_and_caches() {
        let mut s = Store::new();
        s.insert((0, 4), vec![10, 0]);
        let m = ServingModel::from_stores(meta(2, 1), vec![s], 1 << 20).unwrap();
        let p = m.proposal(4);
        for t in 0..2 {
            assert!((p.qw[t] - m.phi(4, t)).abs() < 1e-15);
        }
        assert!((p.qsum - (p.qw[0] + p.qw[1])).abs() < 1e-15);
        let p2 = m.proposal(4);
        assert!(Arc::ptr_eq(&p, &p2), "second lookup must hit the cache");
        // Unseen words get the smoothed-zero proposal, not a panic.
        let p0 = m.proposal(9);
        assert!(p0.qsum > 0.0);
    }
}
