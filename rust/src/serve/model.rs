//! The frozen serving model: a snapshot directory's merged statistics
//! behind the family-generic [`ServingFamily`] abstraction.
//!
//! Training servers each snapshot their ring partition of the shared
//! matrices ([`crate::ps::snapshot`]); the slots' key sets are disjoint
//! by consistent hashing, so the global statistics are the row-wise sum
//! of every `server_slot*.snap` in the directory. The v2+ snapshot header
//! carries the hyperparameters and the ring geometry — and, since v3, the
//! table-side hyperparameters — making the directory fully
//! self-describing: [`ServingModel::load_dir`] dispatches to the right
//! family (LDA, PDP, or HDP) with no training config in sight. v4
//! checkpoint directories (manifest + immutable segments) load through
//! the same path — [`crate::ps::snapshot::load_slot_file`] replays each
//! slot's segment set into the identical store a full dump would carry.
//!
//! The model owns the [`AliasCache`] of per-word proposals. A cached
//! [`WordProposal`] holds the word's frozen φ row plus an alias table
//! over the *prior-weighted* weights `prior_t·φ(w,t)`, which is exactly
//! the dense component of the fold-in conditional
//! `p(z=t) ∝ (n_td + prior_t)·φ(w,t)` — so the MH-Walker proposal is
//! exact for every family and the acceptance ratio is identically 1.

use std::path::Path;
use std::sync::Arc;

use super::cache::{AliasCache, CacheStats, WordProposal};
use super::family::{family_from_stores_sliced, ServingFamily};
use crate::config::ModelKind;
use crate::eval::perplexity::TopicModelView;
use crate::ps::ring::Ring;
use crate::ps::snapshot::{self, SnapshotMeta, Store};
use crate::sampler::alias::AliasTable;
use crate::Result;

/// Default alias-cache budget (64 MiB ≈ 3k resident tables at K=1024).
pub const DEFAULT_CACHE_BYTES: usize = 64 << 20;

/// One decoded slot file, as [`ServingModel::load_dir_slots`] returns it:
/// the store plus everything the resident diff cache needs to recognize
/// it again (`segments` is `None` for v1–v3 full dumps).
struct LoadedSlot {
    name: String,
    meta: Option<SnapshotMeta>,
    store: Store,
    generation: u64,
    segments: Option<Vec<snapshot::SegmentRef>>,
}

/// How the last reload through [`ServingModel::load_dir_stores_cached`]
/// actually loaded: a whole-directory decode, or a generation-diff
/// overlay of only the segments written since the previous load.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReloadStats {
    /// `true` when every slot file was fully decoded; `false` when only
    /// segments newer than the resident watermarks were read.
    pub full: bool,
    /// Segment files opened on the diff path (0 on a full load — and on
    /// a diff reload of an unchanged directory).
    pub segments: usize,
    /// Rows applied from those segments.
    pub rows: usize,
}

/// Decoded slot stores kept resident between reloads, with the segment
/// watermark each was replayed to. [`ServingModel::load_dir_stores_cached`]
/// consults and refreshes this so `serve --watch` reloads of a v4
/// checkpoint stream pay `O(segments written since)` instead of
/// re-decoding the whole model every poll.
pub struct ResidentStores {
    /// Header of the load the stores came from (`run_id` gates the diff).
    meta: SnapshotMeta,
    /// Per slot file, in sorted-name order (the loaders' merge order).
    slots: Vec<ResidentSlot>,
}

/// One slot's resident state: its decoded store and the exact segment
/// list (sorted by generation) that store was replayed from.
struct ResidentSlot {
    name: String,
    generation: u64,
    segments: Vec<snapshot::SegmentRef>,
    store: Store,
}

/// Immutable family statistics + lazily-built per-word alias tables.
pub struct ServingModel {
    meta: SnapshotMeta,
    family: Box<dyn ServingFamily>,
    k: usize,
    vocab: usize,
    /// Cached document-side prior masses `prior_t = doc_prior(t)`.
    priors: Box<[f64]>,
    /// `Σ_t prior_t` — the fold-in smoothing total.
    prior_total: f64,
    cache: AliasCache,
}

fn f64_eq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits()
}

fn tables_eq(
    a: &Option<snapshot::TableHyper>,
    b: &Option<snapshot::TableHyper>,
) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(a), Some(b)) => {
            f64_eq(a.discount, b.discount)
                && f64_eq(a.concentration, b.concentration)
                && f64_eq(a.root, b.root)
        }
        _ => false,
    }
}

impl ServingModel {
    /// Load and merge every `server_slot*.snap` under `dir` with the
    /// default cache budget.
    pub fn load_dir(dir: &Path) -> Result<ServingModel> {
        Self::load_dir_with_budget(dir, DEFAULT_CACHE_BYTES)
    }

    /// Load with an explicit alias-cache byte budget.
    pub fn load_dir_with_budget(dir: &Path, cache_bytes: usize) -> Result<ServingModel> {
        let (meta, stores) = Self::load_dir_stores(dir)?;
        Self::from_stores(meta, stores, cache_bytes)
    }

    /// Read, decode, and cross-validate every `server_slot*.snap` under
    /// `dir`, returning the shared header plus the per-slot stores in
    /// file-name order (a deterministic merge order). Shared by the
    /// single-model loader above and the multi-replica
    /// [`ReplicaSet`](super::router::ReplicaSet) loader, which builds one
    /// vocabulary slice per replica from one decode of the same stores.
    pub fn load_dir_stores(dir: &Path) -> Result<(SnapshotMeta, Vec<Store>)> {
        let (meta, stores, _) = Self::load_dir_stores_versioned(dir)?;
        Ok((meta, stores))
    }

    /// [`load_dir_stores`](Self::load_dir_stores), additionally returning
    /// each slot's segment **generation** (0 for full-dump v1–v3 files) in
    /// the same order as the stores. The generation-diff reload compares
    /// these against its resident watermarks to decide whether overlaying
    /// only the newer segments is valid.
    pub fn load_dir_stores_versioned(dir: &Path) -> Result<(SnapshotMeta, Vec<Store>, Vec<u64>)> {
        let (meta, slots) = Self::load_dir_slots(dir)?;
        let (stores, generations) = slots.into_iter().map(|s| (s.store, s.generation)).unzip();
        Ok((meta, stores, generations))
    }

    /// The full-decode loader behind every directory load: read and
    /// cross-validate each slot file, keeping its name and (for v4
    /// manifests) its segment references alongside the decoded store.
    fn load_dir_slots(dir: &Path) -> Result<(SnapshotMeta, Vec<LoadedSlot>)> {
        let mut slots: Vec<LoadedSlot> = Vec::new();
        let entries = std::fs::read_dir(dir)
            .map_err(|e| anyhow::anyhow!("cannot read snapshot dir {}: {e}", dir.display()))?;
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            if !snapshot::is_slot_snapshot_name(&name) {
                continue;
            }
            // Any format v1–v4: full dumps decode in place, a v4 manifest
            // replays its segment set (a torn referenced segment is a
            // hard error naming the file).
            let (m, store, generation, segments) = snapshot::load_slot_file_tracked(dir, &name)?;
            slots.push(LoadedSlot {
                name,
                meta: m,
                store,
                generation,
                segments,
            });
        }
        slots.sort_by(|a, b| a.name.cmp(&b.name));
        anyhow::ensure!(
            !slots.is_empty(),
            "no server_slot*.snap files in {} — train with --snapshot-dir first",
            dir.display()
        );
        let meta = slots
            .iter()
            .find_map(|s| s.meta.clone())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "snapshots in {} predate the v2 format and carry no \
                     hyperparameters; re-train to serve them",
                    dir.display()
                )
            })?;
        // A v1 file next to v2+ files is a stale slot from an earlier run:
        // it would dodge every consistency check below (no header to
        // compare), so refuse outright rather than merge mixed runs.
        anyhow::ensure!(
            slots.iter().all(|s| s.meta.is_some()),
            "snapshot dir {} mixes v2+ and pre-v2 slot files — stale \
             snapshots from an earlier run; re-train to regenerate",
            dir.display()
        );
        for s in slots.iter() {
            if let Some(m) = &s.meta {
                anyhow::ensure!(
                    m.k == meta.k && m.n_servers == meta.n_servers && m.vnodes == meta.vnodes,
                    "snapshot slots disagree on ring/model geometry \
                     (K {} vs {}, servers {} vs {})",
                    m.k,
                    meta.k,
                    m.n_servers,
                    meta.n_servers
                );
                // Same-geometry slots from *different runs* would merge
                // silently otherwise — the ring check can't catch them.
                // The v3 `run_id` nonce is the decisive test: it differs
                // between runs even when every configured hyperparameter
                // matches (e.g. a watch-triggered reload racing a
                // same-config retrain's slot writes).
                anyhow::ensure!(
                    m.model == meta.model
                        && f64_eq(m.alpha, meta.alpha)
                        && f64_eq(m.beta, meta.beta)
                        && m.vocab_size == meta.vocab_size
                        && m.iterations == meta.iterations
                        && m.run_id == meta.run_id
                        && tables_eq(&m.tables, &meta.tables),
                    "snapshot slots disagree on run/hyperparameters \
                     ({} α={} β={} V={} iters={} run={:#x} tables {:?} vs \
                     {} α={} β={} V={} iters={} run={:#x} tables {:?}) — \
                     mixed runs?",
                    m.model,
                    m.alpha,
                    m.beta,
                    m.vocab_size,
                    m.iterations,
                    m.run_id,
                    m.tables,
                    meta.model,
                    meta.alpha,
                    meta.beta,
                    meta.vocab_size,
                    meta.iterations,
                    meta.run_id,
                    meta.tables
                );
            }
        }
        anyhow::ensure!(
            slots.len() == meta.n_servers as usize,
            "expected {} slot snapshots, found {} — partial snapshot dir",
            meta.n_servers,
            slots.len()
        );
        // Ring-assignment sanity: every key must live in the slot that
        // owns its arc. A mismatch means mixed snapshot generations.
        let ring = Ring::new(meta.n_servers as usize, meta.vnodes as usize);
        let mut misrouted = 0u64;
        for s in slots.iter() {
            if let Some(m) = &s.meta {
                for &(matrix, word) in s.store.keys() {
                    if ring.route(matrix, word) != m.slot {
                        misrouted += 1;
                    }
                }
            }
        }
        if misrouted > 0 {
            crate::warn!(
                "serve",
                "{misrouted} snapshot keys routed outside their slot — \
                 snapshot dir may mix runs"
            );
        }
        Ok((meta, slots))
    }

    /// Directory load through a **resident-store cache**: the
    /// generation-diff reload path behind [`super::handle::ServingHandle`]
    /// and [`super::router::ReplicaSet`].
    ///
    /// When `cache` holds the decoded stores of a previous load and the
    /// directory's slot files are v4 manifests whose histories are
    /// append-only extensions of the cached watermarks (same slot set,
    /// same `run_id`, every segment at or below the watermark identical
    /// to what the resident stores were replayed from), only the
    /// segments **newer** than each watermark are read and overlaid onto
    /// clones of the resident stores — `O(delta)` file I/O and decode
    /// instead of `O(model)`. The overlay replays exactly the suffix a
    /// full replay would apply on top of the identical prefix state, so
    /// the returned stores are bit-identical to a full decode of the same
    /// directory; anything the eligibility checks cannot prove falls back
    /// to the full loader (which re-validates with its usual
    /// diagnostics). On either path the cache is refreshed (or cleared,
    /// for pre-v4 directories) so the next reload diffs against this one.
    ///
    /// The cache trades memory for reload latency: it keeps one decoded
    /// copy of every slot store between reloads.
    pub fn load_dir_stores_cached(
        dir: &Path,
        cache: &mut Option<ResidentStores>,
    ) -> Result<(SnapshotMeta, Vec<Store>, ReloadStats)> {
        if let Some(resident) = cache.take() {
            if let Some((meta, stores, fresh, stats)) =
                Self::overlay_newer_segments(dir, &resident)?
            {
                *cache = Some(fresh);
                return Ok((meta, stores, stats));
            }
        }
        let (meta, slots) = Self::load_dir_slots(dir)?;
        // Only an all-v4 directory can seed the diff cache: full dumps
        // carry no segment history to diff against.
        if slots.iter().all(|s| s.segments.is_some()) {
            *cache = Some(ResidentStores {
                meta: meta.clone(),
                slots: slots
                    .iter()
                    .map(|s| {
                        let mut segments = s.segments.clone().unwrap_or_default();
                        segments.sort_by_key(|r| r.generation);
                        ResidentSlot {
                            name: s.name.clone(),
                            generation: s.generation,
                            segments,
                            store: s.store.clone(),
                        }
                    })
                    .collect(),
            });
        }
        let stores = slots.into_iter().map(|s| s.store).collect();
        Ok((
            meta,
            stores,
            ReloadStats {
                full: true,
                segments: 0,
                rows: 0,
            },
        ))
    }

    /// The diff path of [`load_dir_stores_cached`]: `Ok(None)` means
    /// "not eligible, take the full path"; `Err` means the directory is
    /// damaged in a way a full reload would also refuse (e.g. a manifest
    /// referencing a torn segment).
    #[allow(clippy::type_complexity)]
    fn overlay_newer_segments(
        dir: &Path,
        resident: &ResidentStores,
    ) -> Result<Option<(SnapshotMeta, Vec<Store>, ResidentStores, ReloadStats)>> {
        let mut names: Vec<String> = match std::fs::read_dir(dir) {
            Ok(entries) => entries
                .flatten()
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| snapshot::is_slot_snapshot_name(n))
                .collect(),
            Err(_) => return Ok(None),
        };
        names.sort();
        if names.len() != resident.slots.len()
            || names
                .iter()
                .zip(&resident.slots)
                .any(|(n, s)| *n != s.name)
        {
            return Ok(None); // slot set changed — different run shape
        }
        let mut manifests = Vec::with_capacity(names.len());
        for name in &names {
            match snapshot::read_manifest(&dir.join(name)) {
                Some(m) => manifests.push(m),
                None => return Ok(None), // pre-v4 dump (or unreadable)
            }
        }
        // Same run and geometry as the resident state; manifests must
        // also agree among themselves (slot id aside) — anything less
        // goes through the full loader and its mixed-run diagnostics.
        let meta = manifests[0].meta.clone();
        if meta.run_id != resident.meta.run_id
            || meta.k != resident.meta.k
            || meta.n_servers != resident.meta.n_servers
            || meta.vnodes != resident.meta.vnodes
        {
            return Ok(None);
        }
        for m in &manifests[1..] {
            let mut a = m.meta.clone();
            a.slot = meta.slot;
            if a != meta {
                return Ok(None);
            }
        }
        let mut stats = ReloadStats {
            full: false,
            segments: 0,
            rows: 0,
        };
        let mut fresh = Vec::with_capacity(manifests.len());
        let mut stores = Vec::with_capacity(manifests.len());
        for (slot, manifest) in resident.slots.iter().zip(&manifests) {
            let mut segments = manifest.segments.clone();
            segments.sort_by_key(|r| r.generation);
            // Append-only since the watermark: every referenced segment
            // at or below it must be exactly the one the resident store
            // replayed (name, kind, generation, length, checksum). A
            // rebase or a failover-restarted segment log rewrites
            // history — checksums diverge and we fall back to full.
            if manifest.generation < slot.generation {
                return Ok(None);
            }
            let split = segments.partition_point(|r| r.generation <= slot.generation);
            if segments[..split] != slot.segments[..] {
                return Ok(None);
            }
            let mut store = slot.store.clone();
            for seg in &segments[split..] {
                let rows = snapshot::load_segment(dir, seg)?;
                if seg.kind == snapshot::SegmentKind::Base {
                    // A base supersedes everything before it (only
                    // reachable here from an empty watermark, but keep
                    // replay semantics exact regardless).
                    store.clear();
                }
                stats.segments += 1;
                stats.rows += rows.len();
                snapshot::apply_segment_rows(&mut store, &rows, manifest.meta.k);
            }
            fresh.push(ResidentSlot {
                name: slot.name.clone(),
                generation: manifest.generation,
                segments,
                store: store.clone(),
            });
            stores.push(store);
        }
        let resident = ResidentStores {
            meta: meta.clone(),
            slots: fresh,
        };
        Ok(Some((meta, stores, resident, stats)))
    }

    /// Build from already-decoded stores (exposed for tests and tools).
    pub fn from_stores(
        meta: SnapshotMeta,
        stores: Vec<Store>,
        cache_bytes: usize,
    ) -> Result<ServingModel> {
        Self::build(meta, &stores, cache_bytes, None)
    }

    /// Build a vocabulary **slice**: per-word rows are materialized only
    /// for words `owned` accepts, while every normalizer (per-topic
    /// totals, document-side priors, the HDP root sticks, the vocabulary
    /// size) is computed over *all* stores — so `φ(w,t)`, the priors, and
    /// the alias proposal of an owned word are bit-identical to the
    /// unsliced model's. The multi-replica router
    /// ([`ReplicaSet`](super::router::ReplicaSet)) loads one slice per
    /// replica, each with its own independent alias cache.
    pub fn from_stores_sliced(
        meta: SnapshotMeta,
        stores: &[Store],
        cache_bytes: usize,
        owned: &dyn Fn(u32) -> bool,
    ) -> Result<ServingModel> {
        Self::build(meta, stores, cache_bytes, Some(owned))
    }

    /// Build **all** `parts` vocabulary slices from one shared scan of
    /// the decoded stores
    /// ([`families_from_stores_partitioned`](super::family::families_from_stores_partitioned)),
    /// each slice with its own alias cache of `cache_bytes`. Bit-identical
    /// to `parts` separate [`from_stores_sliced`](Self::from_stores_sliced)
    /// calls at ~1/N of the scan cost — the replica-set load/reload path.
    pub fn slices_from_stores(
        meta: SnapshotMeta,
        stores: &[Store],
        cache_bytes: usize,
        parts: usize,
        owner: &dyn Fn(u32) -> u32,
    ) -> Result<Vec<ServingModel>> {
        let families =
            super::family::families_from_stores_partitioned(&meta, stores, parts, owner)?;
        families
            .into_iter()
            .map(|family| Self::from_family(meta.clone(), family, cache_bytes))
            .collect()
    }

    fn build(
        meta: SnapshotMeta,
        stores: &[Store],
        cache_bytes: usize,
        owned: Option<&dyn Fn(u32) -> bool>,
    ) -> Result<ServingModel> {
        let family = family_from_stores_sliced(&meta, stores, owned)?;
        Self::from_family(meta, family, cache_bytes)
    }

    fn from_family(
        meta: SnapshotMeta,
        family: Box<dyn ServingFamily>,
        cache_bytes: usize,
    ) -> Result<ServingModel> {
        let k = family.k();
        let vocab = family.vocab();
        let priors: Box<[f64]> = (0..k).map(|t| family.doc_prior(t).max(0.0)).collect();
        let prior_total: f64 = priors.iter().sum();
        anyhow::ensure!(
            prior_total > 0.0,
            "{} snapshot yields a zero document-side prior — corrupt table \
             statistics?",
            meta.model
        );
        Ok(ServingModel {
            k,
            vocab,
            priors,
            prior_total,
            cache: AliasCache::new(k, cache_bytes, 16),
            family,
            meta,
        })
    }

    /// Topic count.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Vocabulary size the model serves.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The model family these statistics belong to.
    pub fn kind(&self) -> ModelKind {
        self.family.kind()
    }

    /// Error unless `requested` belongs to the same serving family as the
    /// snapshot's recorded model — the `serve --model` contradiction
    /// check (a PDP query against LDA statistics would silently produce
    /// garbage mixtures otherwise).
    pub fn ensure_family(&self, requested: ModelKind) -> Result<()> {
        anyhow::ensure!(
            requested.family_name() == self.kind().family_name(),
            "--model {} contradicts the snapshot's recorded family: the \
             directory was trained as {} (family {:?}, requested {:?})",
            requested.as_str(),
            self.meta.model,
            self.kind().family_name(),
            requested.family_name()
        );
        Ok(())
    }

    /// The snapshot metadata this model was loaded from.
    pub fn meta(&self) -> &SnapshotMeta {
        &self.meta
    }

    /// Total (clamped) token mass in the frozen primary statistic.
    pub fn total_tokens(&self) -> i64 {
        self.family.total_tokens()
    }

    /// Document-side prior masses per topic.
    pub fn priors(&self) -> &[f64] {
        &self.priors
    }

    /// `Σ_t prior_t`.
    pub fn prior_total(&self) -> f64 {
        self.prior_total
    }

    /// Alias-cache statistics.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The word's frozen dense proposal, from the cache (built on miss).
    pub fn proposal(&self, w: u32) -> Arc<WordProposal> {
        self.cache.get_or_build(w, || self.build_proposal(w))
    }

    /// The O(K) table build behind [`proposal`](Self::proposal) and the
    /// pre-warm path.
    fn build_proposal(&self, w: u32) -> WordProposal {
        let mut phi = Vec::with_capacity(self.k);
        let mut q = Vec::with_capacity(self.k);
        let mut qsum = 0.0;
        for t in 0..self.k {
            let p = self.family.phi(w, t);
            let weighted = self.priors[t] * p;
            phi.push(p);
            q.push(weighted);
            qsum += weighted;
        }
        WordProposal {
            table: AliasTable::build(&q),
            phi: phi.into_boxed_slice(),
            qsum,
        }
    }

    /// Whether this model materializes per-word statistics for `w` —
    /// `false` on a vocabulary slice for words it does not own, and on
    /// any model for words never observed in training.
    pub fn has_row(&self, w: u32) -> bool {
        self.family.has_row(w)
    }

    /// Words with resident alias tables, coldest-first (the pre-warm
    /// handoff set a reloading generation inherits).
    pub fn resident_words(&self) -> Vec<u32> {
        self.cache.resident_words()
    }

    /// Eagerly build alias tables for `words` (skipping already-resident
    /// ones and out-of-vocabulary ids); returns how many were built.
    /// Builds count into [`CacheStats::prewarmed`], never `misses`.
    pub fn prewarm_words(&self, words: &[u32]) -> usize {
        let mut built = 0;
        for &w in words {
            if (w as usize) < self.vocab && self.cache.prewarm(w, || self.build_proposal(w)) {
                built += 1;
            }
        }
        built
    }

    /// Pre-warm this model's alias cache from the resident word set of
    /// the `outgoing` generation, coldest-first — so the hottest words
    /// are inserted last and win this cache's byte budget. Fixes the
    /// post-swap p99 spike of a cold reloaded cache: the first query for
    /// a previously-hot word is a hit, not an O(K) rebuild. No-op when
    /// the models disagree on topic count (the swap will be refused
    /// anyway).
    pub fn prewarm_from(&self, outgoing: &ServingModel) -> usize {
        if outgoing.k != self.k {
            return 0;
        }
        self.prewarm_words(&outgoing.resident_words())
    }
}

impl TopicModelView for ServingModel {
    fn k(&self) -> usize {
        self.k
    }

    fn phi(&self, w: u32, t: usize) -> f64 {
        self.family.phi(w, t)
    }

    fn doc_prior(&self, t: usize) -> f64 {
        self.family.doc_prior(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::snapshot::TableHyper;

    fn meta(k: u32, n_servers: u32) -> SnapshotMeta {
        SnapshotMeta {
            model: "AliasLDA".to_string(),
            k,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 10,
            slot: 0,
            n_servers,
            vnodes: 8,
            iterations: 1,
            run_id: 0,
            tables: None,
        }
    }

    #[test]
    fn merges_slot_stores() {
        let mut a = Store::new();
        a.insert((0, 1), vec![3, 0, 1].into());
        let mut b = Store::new();
        b.insert((0, 2), vec![0, 5, 0].into());
        b.insert((0, 1), vec![1, 0, 0].into()); // overlap adds
        b.insert((1, 2), vec![9, 9, 9].into()); // table matrix, not primary mass
        let m = ServingModel::from_stores(meta(3, 2), vec![a, b], 1 << 20).unwrap();
        assert_eq!(m.k(), 3);
        assert_eq!(m.vocab(), 10);
        assert_eq!(m.kind(), ModelKind::AliasLda);
        assert_eq!(m.total_tokens(), 4 + 1 + 5);
        // φ normalizes against clamped totals.
        let phi_sum: f64 = (0..10).map(|w| m.phi(w, 1)).sum();
        assert!((phi_sum - 1.0).abs() < 1e-9, "φ(·|t) sums to {phi_sum}");
        // LDA priors are the flat α row.
        assert_eq!(m.priors(), &[0.1, 0.1, 0.1]);
        assert!((m.prior_total() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn rejects_v2_pdp_and_zero_k() {
        let mut pdp = meta(4, 1);
        pdp.model = "AliasPDP".to_string(); // no tables hyper → v2-era
        assert!(ServingModel::from_stores(pdp, vec![Store::new()], 1024).is_err());
        let mut zero_k = meta(0, 1);
        zero_k.vocab_size = 10;
        assert!(ServingModel::from_stores(zero_k, vec![Store::new()], 1024).is_err());
    }

    #[test]
    fn serves_pdp_snapshots_with_v3_tables() {
        let mut store = Store::new();
        for w in 0..10u32 {
            let (mr, sr) = if w < 5 {
                (vec![40, 0], vec![4, 0])
            } else {
                (vec![0, 40], vec![0, 4])
            };
            store.insert((0, w), mr.into());
            store.insert((1, w), sr.into());
        }
        let mut pdp = meta(2, 1);
        pdp.model = "AliasPDP".to_string();
        pdp.tables = Some(TableHyper {
            discount: 0.1,
            concentration: 10.0,
            root: 0.5,
        });
        let m = ServingModel::from_stores(pdp, vec![store], 1 << 20).unwrap();
        assert_eq!(m.kind(), ModelKind::AliasPdp);
        let phi_sum: f64 = (0..10).map(|w| m.phi(w, 0)).sum();
        assert!((phi_sum - 1.0).abs() < 1e-9, "PDP φ sums to {phi_sum}");
        // Proposal rows carry φ and the prior-weighted mass.
        let p = m.proposal(0);
        assert!((p.phi[0] - m.phi(0, 0)).abs() < 1e-15);
        let expect_qsum: f64 = (0..2).map(|t| m.priors()[t] * m.phi(0, t)).sum();
        assert!((p.qsum - expect_qsum).abs() < 1e-15);
    }

    #[test]
    fn ensure_family_checks_at_family_granularity() {
        let m = ServingModel::from_stores(
            meta(2, 1),
            vec![{
                let mut s = Store::new();
                s.insert((0, 1), vec![3, 1].into());
                s
            }],
            1 << 20,
        )
        .unwrap();
        // Both LDA samplers share the statistic → both accepted.
        assert!(m.ensure_family(ModelKind::AliasLda).is_ok());
        assert!(m.ensure_family(ModelKind::YahooLda).is_ok());
        // Cross-family contradiction → clear error naming both sides.
        let msg = match m.ensure_family(ModelKind::AliasPdp) {
            Ok(()) => panic!("PDP against LDA statistics must be refused"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("AliasPDP") && msg.contains("AliasLDA"), "{msg}");
    }

    #[test]
    fn prewarm_from_carries_the_resident_set_across_generations() {
        let stores = || {
            let mut s = Store::new();
            for w in 0..10u32 {
                s.insert((0, w), if w < 5 { vec![9, 0] } else { vec![0, 9] }.into());
            }
            vec![s]
        };
        let old = ServingModel::from_stores(meta(2, 1), stores(), 1 << 20).unwrap();
        for w in [1u32, 3, 7] {
            old.proposal(w);
        }
        let new = ServingModel::from_stores(meta(2, 1), stores(), 1 << 20).unwrap();
        assert_eq!(new.prewarm_from(&old), 3);
        let st = new.cache_stats();
        assert_eq!((st.prewarmed, st.misses), (3, 0));
        // First post-swap touch of a previously-resident word: a hit,
        // not an O(K) rebuild — and bit-identical to the old table.
        let p = new.proposal(3);
        let st = new.cache_stats();
        assert_eq!((st.hits, st.misses), (1, 0));
        let q = old.proposal(3);
        assert_eq!(p.qsum.to_bits(), q.qsum.to_bits());
    }

    #[test]
    fn proposal_matches_phi_and_caches() {
        let mut s = Store::new();
        s.insert((0, 4), vec![10, 0].into());
        let m = ServingModel::from_stores(meta(2, 1), vec![s], 1 << 20).unwrap();
        let p = m.proposal(4);
        for t in 0..2 {
            assert!((p.phi[t] - m.phi(4, t)).abs() < 1e-15);
        }
        let qsum: f64 = (0..2).map(|t| m.priors()[t] * p.phi[t]).sum();
        assert!((p.qsum - qsum).abs() < 1e-15);
        let p2 = m.proposal(4);
        assert!(Arc::ptr_eq(&p, &p2), "second lookup must hit the cache");
        // Unseen words get the smoothed-zero proposal, not a panic.
        let p0 = m.proposal(9);
        assert!(p0.qsum > 0.0);
    }
}
