//! One serving replica: a vocabulary slice of the model with its own
//! alias cache and its own staged generation.
//!
//! A [`Replica`] owns the slice of the model whose words the set's
//! consistent-hash ring assigns to it ([`super::router::QueryRouter`]) —
//! the paper's model-parallel layout carried over to serving: no replica
//! holds the whole word–topic matrix, and each replica's budgeted alias
//! LRU is touched only by the words it owns, so there is no shared-lock
//! contention between replicas on the cache hot path.
//!
//! Generations swap **per replica** but commit **set-wide**: a reload
//! builds every replica's next slice in one shared scan of the decoded
//! stores ([`ServingModel::slices_from_stores`]), prepares each replica
//! ([`Replica::prepare`] — fault check, pre-warm, stage), and only when
//! every replica has staged does the [`ReplicaSet`] make the new
//! generation visible in one atomic swap. A replica that fails mid-reload
//! (I/O error, or the [`Replica::fail_next_reload`] chaos hook) aborts
//! the commit; the set keeps answering from the old generation and no
//! request is dropped.
//!
//! [`ReplicaSet`]: super::router::ReplicaSet

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::cache::CacheStats;
use super::model::ServingModel;
use crate::Result;

/// One replica of a [`ReplicaSet`](super::router::ReplicaSet): identity,
/// the most recently staged slice, and a fault-injection hook.
pub struct Replica {
    id: u32,
    /// Most recently prepared slice (the per-replica swap target). Only
    /// visible to queries once the set-wide commit publishes it.
    staged: Mutex<Arc<ServingModel>>,
    /// When set, the next [`prepare`](Self::prepare) fails — the
    /// fault-injection hook for reload/failover tests and chaos drills.
    fail_next: AtomicBool,
}

impl Replica {
    /// Wrap an initially-loaded slice as replica `id`.
    pub(super) fn new(id: u32, slice: Arc<ServingModel>) -> Replica {
        Replica {
            id,
            staged: Mutex::new(slice),
            fail_next: AtomicBool::new(false),
        }
    }

    /// This replica's id (its slot on the set's vocabulary ring).
    pub fn id(&self) -> u32 {
        self.id
    }

    /// The most recently staged slice. Equals the committed generation's
    /// slice except in the window between a prepare and its commit (or
    /// after an aborted reload — staged slices of an aborted generation
    /// are never served).
    pub fn staged_model(&self) -> Arc<ServingModel> {
        self.staged.lock().unwrap().clone()
    }

    /// Alias-cache statistics of the staged slice.
    pub fn cache_stats(&self) -> CacheStats {
        self.staged_model().cache_stats()
    }

    /// Fault injection: make the next [`prepare`](Self::prepare) fail as
    /// if this replica dropped mid-reload. One-shot (cleared when it
    /// fires), so a subsequent reload attempt succeeds — the re-install
    /// path the fault tests exercise.
    pub fn fail_next_reload(&self) {
        self.fail_next.store(true, Ordering::SeqCst);
    }

    /// Phase 1 of a set reload: take this replica's next-generation slice
    /// (built by the set's **single shared scan** of the decoded stores —
    /// [`ServingModel::slices_from_stores`]), pre-warm its alias cache
    /// from the outgoing slice's resident word set, and stage it. Returns
    /// the staged slice for the set-wide commit
    /// ([`ReplicaSet::install_stores`](super::router::ReplicaSet::install_stores)).
    /// An injected fault aborts the whole set's reload — the old
    /// generation keeps serving.
    pub fn prepare(
        &self,
        slice: Arc<ServingModel>,
        outgoing: &ServingModel,
    ) -> Result<Arc<ServingModel>> {
        anyhow::ensure!(
            !self.fail_next.swap(false, Ordering::SeqCst),
            "replica {} dropped mid-reload (injected fault)",
            self.id
        );
        // Reloads keep the set's ring (only a resize changes it, and a
        // resize builds fresh replicas rather than preparing these), so
        // the outgoing resident set contains only words this replica
        // still owns.
        slice.prewarm_from(outgoing);
        *self.staged.lock().unwrap() = slice.clone();
        Ok(slice)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::router::QueryRouter;
    use crate::ps::snapshot::{SnapshotMeta, Store};

    fn toy_meta() -> SnapshotMeta {
        SnapshotMeta {
            model: "AliasLDA".to_string(),
            k: 2,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 10,
            slot: 0,
            n_servers: 1,
            vnodes: 8,
            iterations: 1,
            run_id: 0,
            tables: None,
        }
    }

    fn toy_stores() -> Vec<Store> {
        let mut s = Store::new();
        for w in 0..10u32 {
            s.insert((0, w), if w < 5 { vec![6, 0] } else { vec![0, 6] }.into());
        }
        vec![s]
    }

    #[test]
    fn prepare_stages_a_prewarmed_slice_and_faults_fire_once() {
        let router = QueryRouter::new(2);
        let stores = toy_stores();
        // Exercise whichever replica owns word 0 — guaranteed non-empty.
        let id = router.owner(0);
        let build_slice = || {
            Arc::new(
                ServingModel::from_stores_sliced(toy_meta(), &stores, 1 << 20, &|w| {
                    router.owner(w) == id
                })
                .unwrap(),
            )
        };
        let slice0 = build_slice();
        // Make an owned word's table resident in the outgoing slice.
        slice0.proposal(0);
        let r = Replica::new(id, slice0.clone());

        r.fail_next_reload();
        let msg = match r.prepare(build_slice(), &slice0) {
            Ok(_) => panic!("injected fault must fail the prepare"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("injected fault"), "{msg}");
        // One-shot: the retry succeeds and the staged slice is pre-warmed.
        let staged = r.prepare(build_slice(), &slice0).unwrap();
        assert!(Arc::ptr_eq(&staged, &r.staged_model()));
        let st = staged.cache_stats();
        assert_eq!(st.prewarmed, 1, "outgoing resident word must pre-warm");
        assert_eq!(st.misses, 0);
    }
}
