//! Hot-reloadable model handle: a generation-numbered, atomically-swapped
//! pointer to the current [`ServingModel`].
//!
//! Training keeps writing barrier-free snapshots while the inference
//! server runs; [`ServingHandle::reload`] picks a newer snapshot
//! generation up **without restarting the service and without dropping
//! the in-flight micro-batch queue**:
//!
//! * Readers ([`super::service`] workers) resolve
//!   [`ServingHandle::current`] once per micro-batch — an `arc_swap`-style
//!   read: clone an `Arc` under a briefly-held read lock, then serve the
//!   whole batch against that pinned generation lock-free.
//! * [`reload`](ServingHandle::reload) does the expensive part (reading
//!   and merging the slot snapshots, `O(V·K)`) *outside* any lock, then
//!   swaps the pointer under the write lock. Queued queries are never
//!   touched: jobs enqueued before the swap may be answered by either
//!   generation (whichever the draining worker pinned), jobs enqueued
//!   after the swap are answered by the new one, and nothing is dropped
//!   or errored either way.
//! * Every [`InferResult`](super::infer::InferResult) reports the
//!   `generation` that served it, so callers can observe a rollout.
//!
//! Generations are assigned monotonically by the handle (the first loaded
//! model is generation 1); a racing stale install can never roll the
//! visible generation backwards. A reload that would switch model
//! *families* (LDA → PDP, say) is refused — mixtures from different
//! families are not comparable, so that calls for a new server, not a
//! swap.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::infer::{infer_doc, InferConfig, InferResult};
use super::model::{ReloadStats, ResidentStores, ServingModel, DEFAULT_CACHE_BYTES};
use crate::util::rng::Rng;
use crate::Result;

/// A source of pinned, generation-numbered models that answer fold-in
/// queries — implemented by the single-process [`ServingHandle`] and by
/// the multi-replica [`ReplicaSet`](super::router::ReplicaSet). The
/// [`InferenceService`](super::service::InferenceService) workers are
/// written against this trait, so one micro-batching pool serves both
/// topologies unchanged.
pub trait QueryBackend: Send + Sync {
    /// Pin the currently-committed generation for a micro-batch. Cheap;
    /// hold the result for the batch so a concurrent swap can't change
    /// the serving state mid-batch.
    fn pin(&self) -> Arc<dyn PinnedGeneration>;

    /// The currently-visible (committed) generation number.
    fn generation(&self) -> u64;
}

/// One immutable pinned generation: answers queries until dropped (old
/// generations stay alive for whoever still pins them).
pub trait PinnedGeneration: Send + Sync {
    /// The generation number of this pin.
    fn generation(&self) -> u64;

    /// Fold `tokens` in against this generation. Deterministic given
    /// `rng`; fills [`InferResult::generation`] (and, for routed
    /// backends, [`InferResult::served_by`]).
    fn infer(&self, tokens: &[u32], cfg: &InferConfig, rng: &mut Rng) -> InferResult;
}

/// One loaded model plus the generation number the handle assigned it.
pub struct ModelGeneration {
    /// Monotonic generation (1 = the initially loaded model).
    pub generation: u64,
    /// The frozen model of this generation.
    pub model: Arc<ServingModel>,
}

impl PinnedGeneration for ModelGeneration {
    fn generation(&self) -> u64 {
        self.generation
    }

    fn infer(&self, tokens: &[u32], cfg: &InferConfig, rng: &mut Rng) -> InferResult {
        let mut res = infer_doc(&self.model, tokens, cfg, rng);
        res.generation = self.generation;
        res
    }
}

/// Shared, swappable access to the currently-served model.
pub struct ServingHandle {
    current: RwLock<Arc<ModelGeneration>>,
    /// Next generation number to hand out.
    next_gen: AtomicU64,
    /// Alias-cache budget applied to reloaded models.
    cache_bytes: usize,
    /// The directory backing this handle (None for in-memory models).
    dir: Mutex<Option<PathBuf>>,
    /// Decoded stores of the last committed load — the generation-diff
    /// reload cache (None until a v4 directory loads, cleared on any
    /// reload error so the next attempt decodes from scratch). Also the
    /// reload serialization point: the lock is held across the whole
    /// load-and-commit so two concurrent reloads cannot interleave their
    /// overlays.
    resident: Mutex<Option<ResidentStores>>,
    /// How the last successful directory load actually loaded.
    last_reload: Mutex<ReloadStats>,
}

impl ServingHandle {
    /// Load generation 1 from a snapshot directory with the default
    /// cache budget.
    pub fn load_dir(dir: &Path) -> Result<Arc<ServingHandle>> {
        Self::load_dir_with_budget(dir, DEFAULT_CACHE_BYTES)
    }

    /// Load generation 1 with an explicit alias-cache byte budget.
    pub fn load_dir_with_budget(dir: &Path, cache_bytes: usize) -> Result<Arc<ServingHandle>> {
        let mut resident = None;
        let (meta, stores, stats) = ServingModel::load_dir_stores_cached(dir, &mut resident)?;
        let model = ServingModel::from_stores(meta, stores, cache_bytes)?;
        let h = Self::new(model, cache_bytes, Some(dir.to_path_buf()));
        *h.resident.lock().unwrap() = resident;
        *h.last_reload.lock().unwrap() = stats;
        Ok(Arc::new(h))
    }

    /// Wrap an already-built model (tests, tools, synthetic stores).
    pub fn from_model(model: ServingModel) -> Arc<ServingHandle> {
        Arc::new(Self::new(model, DEFAULT_CACHE_BYTES, None))
    }

    fn new(model: ServingModel, cache_bytes: usize, dir: Option<PathBuf>) -> ServingHandle {
        ServingHandle {
            current: RwLock::new(Arc::new(ModelGeneration {
                generation: 1,
                model: Arc::new(model),
            })),
            next_gen: AtomicU64::new(2),
            cache_bytes,
            dir: Mutex::new(dir),
            resident: Mutex::new(None),
            last_reload: Mutex::new(ReloadStats::default()),
        }
    }

    /// The current generation pointer. Cheap (one `Arc` clone under a
    /// briefly-held read lock); hold the result for the duration of a
    /// batch so a concurrent swap can't change the model mid-batch.
    pub fn current(&self) -> Arc<ModelGeneration> {
        self.current.read().unwrap().clone()
    }

    /// The currently-served model.
    pub fn model(&self) -> Arc<ServingModel> {
        self.current().model.clone()
    }

    /// The currently-visible generation number.
    pub fn generation(&self) -> u64 {
        self.current.read().unwrap().generation
    }

    /// The snapshot directory backing this handle, if any.
    pub fn dir(&self) -> Option<PathBuf> {
        self.dir.lock().unwrap().clone()
    }

    /// Assign the next generation number to `model` and swap it in if it
    /// is still the newest. Returns `(generation, true)` on a committed
    /// swap; `(live_generation, false)` when a racing install already
    /// went newer (the loser's model is dropped, nothing rolls back).
    /// The family check and the `dir` update happen under the same write
    /// lock as the swap, so neither [`install`](Self::install) nor a
    /// racing [`reload`](Self::reload) can ever put a different family —
    /// or a directory that never went live — behind a serving handle.
    fn commit(&self, model: ServingModel, dir: Option<&Path>) -> Result<(u64, bool)> {
        let generation = self.next_gen.fetch_add(1, Ordering::SeqCst);
        let fresh = Arc::new(ModelGeneration {
            generation,
            model: Arc::new(model),
        });
        let mut cur = self.current.write().unwrap();
        anyhow::ensure!(
            fresh.model.kind().family_name() == cur.model.kind().family_name(),
            "cannot swap the serving family from {} to {} — start a new \
             server for a different family instead",
            cur.model.meta().model,
            fresh.model.meta().model
        );
        // Same guard for the model shape: clients size per-topic buffers
        // from responses, so θ must keep its length across generations.
        anyhow::ensure!(
            fresh.model.k() == cur.model.k(),
            "cannot swap in a snapshot with a different topic count \
             (K {} → {}) — restart the server to change model shape",
            cur.model.k(),
            fresh.model.k()
        );
        // Monotonic: two racing installs commit in generation order, so a
        // slower loader that drew the smaller number can never clobber a
        // newer generation that already went live.
        if fresh.generation > cur.generation {
            *cur = fresh;
            if let Some(d) = dir {
                *self.dir.lock().unwrap() = Some(d.to_path_buf());
            }
            Ok((generation, true))
        } else {
            Ok((cur.generation, false))
        }
    }

    /// Install an already-built model as the next generation and return
    /// the generation now live (the new one, or — if a racing install
    /// already went newer — that newer one). Errors if `model` belongs
    /// to a different serving family than the one being served. Used by
    /// [`reload`](Self::reload) and by tests that synthesize models
    /// without a snapshot directory.
    pub fn install(&self, model: ServingModel) -> Result<u64> {
        Ok(self.commit(model, None)?.0)
    }

    /// Load a (presumably newer) snapshot generation from `dir` and swap
    /// it in. The load runs on the caller's thread with no serving lock
    /// held — call from a background thread to keep serving undisturbed;
    /// the swap itself is O(1). When the directory is a v4 checkpoint
    /// whose segment history extends the resident cache's watermark, only
    /// the segments written since the last load are read
    /// ([`ServingModel::load_dir_stores_cached`]) — and the rebuilt model
    /// goes through the same [`ServingModel::from_stores`] terminal path
    /// as a full decode, so the committed generation is bit-identical
    /// either way. Returns the new generation number; on error (a
    /// different family, or losing a race against a concurrent newer
    /// install) the handle keeps serving its current generation
    /// untouched, its backing directory is not repointed, and the diff
    /// cache is dropped so the next attempt decodes from scratch.
    pub fn reload(&self, dir: &Path) -> Result<u64> {
        let mut resident = self.resident.lock().unwrap();
        let loaded: Result<(u64, ReloadStats)> = (|| {
            let (meta, stores, stats) = ServingModel::load_dir_stores_cached(dir, &mut resident)?;
            let model = ServingModel::from_stores(meta, stores, self.cache_bytes)?;
            // Pre-warm the incoming generation's alias cache from the
            // outgoing one's resident word set (still outside the swap
            // lock): post-swap queries for previously-hot words hit
            // instead of paying a cold O(K) rebuild each.
            model.prewarm_from(&self.model());
            let (generation, won) = self.commit(model, Some(dir))?;
            anyhow::ensure!(
                won,
                "reload superseded: generation {generation} was installed \
                 concurrently and is newer; this load was discarded"
            );
            Ok((generation, stats))
        })();
        match loaded {
            Ok((generation, stats)) => {
                *self.last_reload.lock().unwrap() = stats;
                Ok(generation)
            }
            Err(e) => {
                *resident = None;
                Err(e)
            }
        }
    }

    /// How the last successful directory load actually loaded: a full
    /// decode, or a generation-diff overlay (and of how many segments /
    /// rows). The `serve --watch` loop logs this per reload.
    pub fn last_reload_stats(&self) -> ReloadStats {
        *self.last_reload.lock().unwrap()
    }

    /// [`reload`](Self::reload) from the directory this handle was last
    /// loaded from (the `serve --watch` path).
    pub fn reload_latest(&self) -> Result<u64> {
        let dir = self
            .dir()
            .ok_or_else(|| anyhow::anyhow!("handle has no backing snapshot directory"))?;
        self.reload(&dir)
    }
}

impl QueryBackend for ServingHandle {
    fn pin(&self) -> Arc<dyn PinnedGeneration> {
        self.current()
    }

    fn generation(&self) -> u64 {
        ServingHandle::generation(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::snapshot::{self, SnapshotMeta, Store};

    fn toy_meta(model: &str) -> SnapshotMeta {
        SnapshotMeta {
            model: model.to_string(),
            k: 2,
            alpha: 0.1,
            beta: 0.01,
            vocab_size: 10,
            slot: 0,
            n_servers: 1,
            vnodes: 8,
            iterations: 1,
            run_id: 0,
            tables: None,
        }
    }

    fn toy_model(weight: i32) -> ServingModel {
        let mut store = Store::new();
        for w in 0..10u32 {
            let row = if w < 5 { vec![weight, 0] } else { vec![0, weight] };
            store.insert((0, w), row.into());
        }
        ServingModel::from_stores(toy_meta("AliasLDA"), vec![store], 1 << 20).unwrap()
    }

    #[test]
    fn generations_start_at_one_and_increase() {
        let h = ServingHandle::from_model(toy_model(10));
        assert_eq!(h.generation(), 1);
        assert_eq!(h.current().model.total_tokens(), 100);
        let g2 = h.install(toy_model(20)).unwrap();
        assert_eq!(g2, 2);
        assert_eq!(h.generation(), 2);
        assert_eq!(h.current().model.total_tokens(), 200);
        // Old generations stay alive for whoever still pins them.
        let pinned = h.current();
        let g3 = h.install(toy_model(30)).unwrap();
        assert_eq!(g3, 3);
        assert_eq!(pinned.generation, 2);
        assert_eq!(pinned.model.total_tokens(), 200);
    }

    #[test]
    fn reload_from_dir_swaps_and_errors_keep_serving() {
        let dir = std::env::temp_dir().join(format!(
            "hplvm_handle_reload_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut store = Store::new();
        store.insert((0, 1), vec![5, 0].into());
        let bytes = snapshot::encode_store_meta(&store, &toy_meta("AliasLDA"));
        snapshot::write_atomic(&dir.join("server_slot0.snap"), &bytes).unwrap();

        let h = ServingHandle::load_dir(&dir).unwrap();
        assert_eq!(h.generation(), 1);
        assert_eq!(h.dir().as_deref(), Some(dir.as_path()));

        // New snapshot content → reload_latest picks it up as gen 2.
        store.insert((0, 2), vec![0, 7].into());
        let bytes = snapshot::encode_store_meta(&store, &toy_meta("AliasLDA"));
        snapshot::write_atomic(&dir.join("server_slot0.snap"), &bytes).unwrap();
        let g = h.reload_latest().unwrap();
        assert_eq!(g, 2);
        assert_eq!(h.model().total_tokens(), 12);

        // A broken directory fails the reload but keeps generation 2 live.
        let empty = dir.join("nope");
        assert!(h.reload(&empty).is_err());
        assert_eq!(h.generation(), 2);
        assert_eq!(h.model().total_tokens(), 12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v4_reload_takes_the_generation_diff_path_bitwise() {
        use crate::eval::perplexity::TopicModelView;
        let dir = std::env::temp_dir().join(format!(
            "hplvm_handle_diff_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut store = Store::new();
        for w in 0..10u32 {
            let row = if w < 5 { vec![9, 0] } else { vec![0, 9] };
            store.insert((0, w), row.into());
        }
        let mut log = snapshot::SegmentLog::new(0);
        log.seal_to(&dir, &store, &toy_meta("AliasLDA")).unwrap();

        let h = ServingHandle::load_dir(&dir).unwrap();
        assert!(h.last_reload_stats().full, "first load decodes fully");

        // Unchanged directory → the diff path opens zero segments.
        let g = h.reload(&dir).unwrap();
        assert_eq!(g, 2);
        let st = h.last_reload_stats();
        assert_eq!((st.full, st.segments, st.rows), (false, 0, 0), "{st:?}");

        // One changed row sealed as a delta → the reload reads exactly
        // that one segment and one row...
        store.insert((0, 3), vec![1, 2].into());
        log.mark_dirty((0, 3));
        log.seal_to(&dir, &store, &toy_meta("AliasLDA")).unwrap();
        let g = h.reload(&dir).unwrap();
        assert_eq!(g, 3);
        let st = h.last_reload_stats();
        assert_eq!((st.full, st.segments, st.rows), (false, 1, 1), "{st:?}");

        // ...and the committed model is bit-identical to a full decode
        // of the same directory (shared `from_stores` terminal path).
        let full = ServingModel::load_dir(&dir).unwrap();
        assert_eq!(h.model().total_tokens(), full.total_tokens());
        for w in 0..10u32 {
            for t in 0..2 {
                assert_eq!(
                    h.model().phi(w, t).to_bits(),
                    full.phi(w, t).to_bits(),
                    "φ({w},{t}) diverged between diff and full reload"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reload_refuses_family_switch() {
        let dir = std::env::temp_dir().join(format!(
            "hplvm_handle_family_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut store = Store::new();
        store.insert((0, 1), vec![5, 3].into());
        store.insert((1, 1), vec![1, 1].into());
        let mut meta = toy_meta("AliasPDP");
        meta.tables = Some(snapshot::TableHyper {
            discount: 0.1,
            concentration: 10.0,
            root: 0.5,
        });
        let bytes = snapshot::encode_store_meta(&store, &meta);
        snapshot::write_atomic(&dir.join("server_slot0.snap"), &bytes).unwrap();

        let h = ServingHandle::from_model(toy_model(10)); // LDA gen 1
        let msg = match h.reload(&dir) {
            Ok(_) => panic!("LDA → PDP swap must be refused"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("family"), "{msg}");
        assert_eq!(h.generation(), 1, "failed reload must not swap");
        // install() hits the same gate at the commit chokepoint — no
        // bypass for pre-built models.
        let pdp_model = ServingModel::from_stores(meta, vec![store], 1 << 20).unwrap();
        assert!(h.install(pdp_model).is_err());
        assert_eq!(h.generation(), 1, "failed install must not swap");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn install_refuses_topic_count_change() {
        // θ length is part of the response contract: same family but a
        // different K must not swap mid-stream.
        let h = ServingHandle::from_model(toy_model(10)); // K = 2
        let mut meta3 = toy_meta("AliasLDA");
        meta3.k = 3;
        let mut store = Store::new();
        store.insert((0, 1), vec![1, 2, 3].into());
        let wide = ServingModel::from_stores(meta3, vec![store], 1 << 20).unwrap();
        let msg = match h.install(wide) {
            Ok(_) => panic!("K=2 → K=3 swap must be refused"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("topic count"), "{msg}");
        assert_eq!(h.generation(), 1, "refused install must not swap");
    }
}
