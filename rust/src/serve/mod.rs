//! Snapshot-backed topic-inference serving.
//!
//! Training answers "what are the topics?"; this layer answers "what
//! topics is *this document* about?" at query time, against statistics a
//! training run snapshotted to disk:
//!
//! * [`model`] — [`ServingModel`]: merge the `server_slot*.snap` ring
//!   partitions into one frozen `n_tw` matrix, self-described by the v2
//!   snapshot hyperparameter header.
//! * [`cache`] — [`AliasCache`]: per-word Walker alias tables built
//!   lazily and evicted LRU under a byte budget (hot Zipf head resident,
//!   long tail rebuilt on demand).
//! * [`infer`] — [`infer_doc`]: fold-in Gibbs over only the
//!   document-side state with the MH-Walker mixture proposal; with φ
//!   frozen the proposal is exact, so the chain mixes in a handful of
//!   sweeps.
//! * [`service`] — [`InferenceService`]: a bounded queue + worker pool
//!   draining queries in micro-batches, with per-request deterministic
//!   RNG streams and back-pressure on overload.
//!
//! ```no_run
//! use hplvm::serve::{InferenceService, ServeConfig, ServingModel};
//! use std::sync::Arc;
//!
//! let model = ServingModel::load_dir(std::path::Path::new("snapshots")).unwrap();
//! let svc = InferenceService::spawn(Arc::new(model), ServeConfig::default());
//! let mixture = svc.infer(vec![3, 17, 42]).unwrap();
//! println!("top topic: {:?}", mixture.top_topics(1));
//! ```

pub mod cache;
pub mod infer;
pub mod model;
pub mod service;

pub use cache::{AliasCache, CacheStats, WordProposal};
pub use infer::{infer_doc, InferConfig, InferResult};
pub use model::ServingModel;
pub use service::{run_queries, synth_queries, InferenceService, ServeConfig, ServeStats};
