//! Snapshot-backed topic-inference serving — family-generic and
//! hot-reloadable.
//!
//! Training answers "what are the topics?"; this layer answers "what
//! topics is *this document* about?" at query time, against statistics a
//! training run snapshotted to disk — for **every** model family the
//! paper spans (LDA, PDP, HDP):
//!
//! * [`family`] — [`ServingFamily`]: the per-family contract "frozen
//!   sufficient statistics → predictive `φ(w,t)` + document-side prior",
//!   with [`LdaFamily`], [`PdpFamily`] (customer + table counts, PYP
//!   predictive), and [`HdpFamily`] (root-stick prior) built from the v3
//!   snapshot header's table section.
//! * [`model`] — [`ServingModel`]: merge the `server_slot*.snap` ring
//!   partitions, dispatch to the family the header records, own the
//!   alias cache.
//! * [`cache`] — [`AliasCache`]: per-word Walker alias tables built
//!   lazily and evicted LRU under a byte budget (hot Zipf head resident,
//!   long tail rebuilt on demand).
//! * [`infer`] — [`infer_doc`]: fold-in Gibbs over only the
//!   document-side state with the MH-Walker mixture proposal; with φ
//!   frozen the proposal is exact for every family, so the chain mixes
//!   in a handful of sweeps.
//! * [`handle`] — [`ServingHandle`]: a generation-numbered, atomically
//!   swapped pointer to the current model. [`ServingHandle::reload`]
//!   picks up newer snapshots without dropping the in-flight queue;
//!   responses report the generation that served them.
//! * [`service`] — [`InferenceService`]: a bounded queue + worker pool
//!   draining queries in micro-batches (each batch pins one generation),
//!   with per-request deterministic RNG streams and back-pressure on
//!   overload.
//!
//! ```no_run
//! use hplvm::serve::{InferenceService, ServeConfig, ServingHandle};
//!
//! let handle = ServingHandle::load_dir(std::path::Path::new("snapshots")).unwrap();
//! let svc = InferenceService::spawn(handle.clone(), ServeConfig::default());
//! let mixture = svc.infer(vec![3, 17, 42]).unwrap();
//! println!("gen {} top topic: {:?}", mixture.generation, mixture.top_topics(1));
//! handle.reload_latest().unwrap(); // swap in newer snapshots, queue intact
//! ```

pub mod cache;
pub mod family;
pub mod handle;
pub mod infer;
pub mod model;
pub mod service;

pub use cache::{AliasCache, CacheStats, WordProposal};
pub use family::{HdpFamily, LdaFamily, PdpFamily, ServingFamily};
pub use handle::{ModelGeneration, ServingHandle};
pub use infer::{infer_doc, InferConfig, InferResult};
pub use model::ServingModel;
pub use service::{run_queries, synth_queries, InferenceService, ServeConfig, ServeStats};
