//! Snapshot-backed topic-inference serving — family-generic and
//! hot-reloadable.
//!
//! Training answers "what are the topics?"; this layer answers "what
//! topics is *this document* about?" at query time, against statistics a
//! training run snapshotted to disk — for **every** model family the
//! paper spans (LDA, PDP, HDP):
//!
//! * [`family`] — [`ServingFamily`]: the per-family contract "frozen
//!   sufficient statistics → predictive `φ(w,t)` + document-side prior",
//!   with [`LdaFamily`], [`PdpFamily`] (customer + table counts, PYP
//!   predictive), and [`HdpFamily`] (root-stick prior) built from the v3
//!   snapshot header's table section.
//! * [`model`] — [`ServingModel`]: merge the `server_slot*.snap` ring
//!   partitions, dispatch to the family the header records, own the
//!   alias cache.
//! * [`cache`] — [`AliasCache`]: per-word Walker alias tables built
//!   lazily and evicted LRU under a byte budget (hot Zipf head resident,
//!   long tail rebuilt on demand).
//! * [`infer`] — [`infer_doc`]: fold-in Gibbs over only the
//!   document-side state with the MH-Walker mixture proposal; with φ
//!   frozen the proposal is exact for every family, so the chain mixes
//!   in a handful of sweeps.
//! * [`handle`] — [`ServingHandle`]: a generation-numbered, atomically
//!   swapped pointer to the current model. [`ServingHandle::reload`]
//!   picks up newer snapshots without dropping the in-flight queue —
//!   pre-warming the incoming generation's alias cache from the outgoing
//!   resident word set — and responses report the generation that served
//!   them. Reloads of a v4 (segmented) checkpoint stream go through a
//!   resident-store diff cache ([`model::ResidentStores`]): only the
//!   segments written since the previous load are read, and
//!   [`model::ReloadStats`] reports which path ran. The [`QueryBackend`] / [`PinnedGeneration`] traits abstract
//!   "pin a generation, answer queries" over both serving topologies.
//! * [`router`] / [`replica`] — multi-replica serving:
//!   [`ReplicaSet`] partitions the vocabulary over N [`Replica`]s with
//!   the same consistent-hash ring training uses ([`crate::ps::ring`]),
//!   each replica holding a model *slice* (its words' rows, global
//!   normalizers) and its own budgeted alias LRU. The [`QueryRouter`]
//!   scatters a document's words to their owners, gathers the
//!   `prior_t·φ(w,t)` proposals, and the fold-in runs against the merged
//!   proposal — bit-identical to the single-replica posterior under a
//!   fixed seed. Reloads prepare per-replica but commit set-wide.
//! * [`service`] — [`InferenceService`]: a bounded queue + worker pool
//!   draining queries in micro-batches (each batch pins one generation
//!   of either backend), with per-request deterministic RNG streams
//!   (sequence-numbered, or caller-named via
//!   [`InferenceService::submit_with_seed`]) and back-pressure on
//!   overload.
//!
//! The network boundary lives one layer up: [`crate::net`] serves either
//! backend over a framed wire protocol on a thread-per-core reactor,
//! feeding decoded requests into this module's micro-batch path.
//!
//! ```no_run
//! use hplvm::serve::{InferenceService, ReplicaSet, ServeConfig, ServingHandle};
//!
//! let handle = ServingHandle::load_dir(std::path::Path::new("snapshots")).unwrap();
//! let svc = InferenceService::spawn(handle.clone(), ServeConfig::default());
//! let mixture = svc.infer(vec![3, 17, 42]).unwrap();
//! println!("gen {} top topic: {:?}", mixture.generation, mixture.top_topics(1));
//! handle.reload_latest().unwrap(); // swap in newer snapshots, queue intact
//!
//! // Scale out: the same service over four vocabulary-sliced replicas.
//! let set = ReplicaSet::load_dir(std::path::Path::new("snapshots"), 4).unwrap();
//! let svc = InferenceService::spawn(set.clone(), ServeConfig::default());
//! let routed = svc.infer(vec![3, 17, 42]).unwrap();
//! println!("replicas {:?} answered", routed.served_by);
//! ```

pub mod cache;
pub mod family;
pub mod handle;
pub mod infer;
pub mod model;
pub mod replica;
pub mod router;
pub mod service;

pub use cache::{AliasCache, CacheStats, WordProposal};
pub use family::{HdpFamily, LdaFamily, PdpFamily, ServingFamily};
pub use handle::{ModelGeneration, PinnedGeneration, QueryBackend, ServingHandle};
pub use infer::{infer_doc, infer_with_proposals, InferConfig, InferResult};
pub use model::{ReloadStats, ResidentStores, ServingModel};
pub use replica::Replica;
pub use router::{QueryRouter, ReplicaSet, SetGeneration, REPLICA_VNODES};
pub use service::{run_queries, synth_queries, InferenceService, ServeConfig, ServeStats};
